//! Dynamic batcher: size-or-deadline batching with a bounded queue.
//!
//! Requests accumulate in a FIFO; a worker receives a batch as soon as
//! either (a) `max_batch` requests are waiting, or (b) the oldest waiting
//! request has aged past `max_wait`.  The queue is bounded (`queue_cap`)
//! — submission fails fast when the system is saturated, which is the
//! backpressure contract the server surfaces to clients.

use super::protocol::{Request, Response};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
    pub queue_cap: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            max_batch: 32,
            max_wait: Duration::from_millis(2),
            queue_cap: 1024,
        }
    }
}

/// A queued request together with its reply channel and enqueue time.
pub struct Pending {
    pub req: Request,
    pub enqueued: Instant,
    pub resp_tx: Sender<Response>,
}

#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    QueueFull,
    Closed,
}

#[derive(Default)]
struct State {
    queue: VecDeque<Pending>,
}

/// Size-or-deadline dynamic batcher.
pub struct DynamicBatcher {
    cfg: BatcherConfig,
    state: Mutex<State>,
    cv: Condvar,
    closed: AtomicBool,
    pub submitted: AtomicU64,
    pub batches: AtomicU64,
}

impl DynamicBatcher {
    pub fn new(cfg: BatcherConfig) -> Self {
        Self {
            cfg,
            state: Mutex::new(State::default()),
            cv: Condvar::new(),
            closed: AtomicBool::new(false),
            submitted: AtomicU64::new(0),
            batches: AtomicU64::new(0),
        }
    }

    pub fn config(&self) -> &BatcherConfig {
        &self.cfg
    }

    /// Enqueue a request; fails fast on saturation or shutdown.
    pub fn submit(&self, p: Pending) -> Result<(), SubmitError> {
        if self.closed.load(Ordering::Acquire) {
            return Err(SubmitError::Closed);
        }
        let mut st = self.state.lock().unwrap();
        if st.queue.len() >= self.cfg.queue_cap {
            return Err(SubmitError::QueueFull);
        }
        st.queue.push_back(p);
        self.submitted.fetch_add(1, Ordering::Relaxed);
        drop(st);
        self.cv.notify_one();
        Ok(())
    }

    pub fn depth(&self) -> usize {
        self.state.lock().unwrap().queue.len()
    }

    /// Block until a batch is ready (or `None` after close + drain).
    pub fn next_batch(&self) -> Option<Vec<Pending>> {
        let mut st = self.state.lock().unwrap();
        loop {
            if !st.queue.is_empty() {
                let ready_by_size = st.queue.len() >= self.cfg.max_batch;
                let oldest_age = st.queue.front().unwrap().enqueued.elapsed();
                let ready_by_age = oldest_age >= self.cfg.max_wait;
                if ready_by_size
                    || ready_by_age
                    || self.closed.load(Ordering::Acquire)
                {
                    let n = st.queue.len().min(self.cfg.max_batch);
                    let batch: Vec<Pending> = st.queue.drain(..n).collect();
                    self.batches.fetch_add(1, Ordering::Relaxed);
                    return Some(batch);
                }
                // Wait out the remaining age budget.
                let remaining = self.cfg.max_wait - oldest_age;
                let (g, _) = self.cv.wait_timeout(st, remaining).unwrap();
                st = g;
            } else {
                if self.closed.load(Ordering::Acquire) {
                    return None;
                }
                let (g, _) = self
                    .cv
                    .wait_timeout(st, Duration::from_millis(50))
                    .unwrap();
                st = g;
            }
        }
    }

    /// Stop accepting new work and wake all workers to drain.
    pub fn close(&self) {
        self.closed.store(true, Ordering::Release);
        self.cv.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::BackendKind;
    use std::sync::mpsc::channel;
    use std::sync::Arc;

    fn mk_pending(id: u64) -> (Pending, std::sync::mpsc::Receiver<Response>) {
        let (tx, rx) = channel();
        (
            Pending {
                req: Request {
                    id,
                    model: "m".into(),
                    backend: BackendKind::Sketch,
                    features: vec![0.0],
                },
                enqueued: Instant::now(),
                resp_tx: tx,
            },
            rx,
        )
    }

    #[test]
    fn batch_forms_at_max_size() {
        let b = DynamicBatcher::new(BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_secs(10),
            queue_cap: 100,
        });
        let mut rxs = Vec::new();
        for i in 0..4 {
            let (p, rx) = mk_pending(i);
            b.submit(p).unwrap();
            rxs.push(rx);
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 4);
        // FIFO order preserved
        let ids: Vec<u64> = batch.iter().map(|p| p.req.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn batch_fires_on_deadline() {
        let b = DynamicBatcher::new(BatcherConfig {
            max_batch: 100,
            max_wait: Duration::from_millis(5),
            queue_cap: 100,
        });
        let (p, _rx) = mk_pending(1);
        let t0 = Instant::now();
        b.submit(p).unwrap();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        let waited = t0.elapsed();
        assert!(waited >= Duration::from_millis(4), "{waited:?}");
        assert!(waited < Duration::from_millis(500), "{waited:?}");
    }

    #[test]
    fn queue_cap_enforced() {
        let b = DynamicBatcher::new(BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_secs(10),
            queue_cap: 2,
        });
        let (p1, _r1) = mk_pending(1);
        let (p2, _r2) = mk_pending(2);
        let (p3, _r3) = mk_pending(3);
        assert!(b.submit(p1).is_ok());
        assert!(b.submit(p2).is_ok());
        assert_eq!(b.submit(p3).unwrap_err(), SubmitError::QueueFull);
    }

    #[test]
    fn close_rejects_and_drains() {
        let b = DynamicBatcher::new(BatcherConfig::default());
        let (p, _r) = mk_pending(1);
        b.submit(p).unwrap();
        b.close();
        let (p2, _r2) = mk_pending(2);
        assert_eq!(b.submit(p2).unwrap_err(), SubmitError::Closed);
        // drain remaining then None
        assert_eq!(b.next_batch().unwrap().len(), 1);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn concurrent_producers_no_loss() {
        let b = Arc::new(DynamicBatcher::new(BatcherConfig {
            max_batch: 16,
            max_wait: Duration::from_millis(1),
            queue_cap: 100_000,
        }));
        let n_threads = 4;
        let per_thread = 500;
        let mut handles = Vec::new();
        for t in 0..n_threads {
            let b = b.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..per_thread {
                    let (p, _rx) = mk_pending((t * per_thread + i) as u64);
                    b.submit(p).unwrap();
                }
            }));
        }
        let consumer = {
            let b = b.clone();
            std::thread::spawn(move || {
                let mut seen = std::collections::HashSet::new();
                let mut max_batch_seen = 0;
                while seen.len() < n_threads * per_thread {
                    if let Some(batch) = b.next_batch() {
                        max_batch_seen = max_batch_seen.max(batch.len());
                        for p in batch {
                            assert!(seen.insert(p.req.id), "dup {}", p.req.id);
                        }
                    }
                }
                (seen.len(), max_batch_seen)
            })
        };
        for h in handles {
            h.join().unwrap();
        }
        let (seen, max_batch_seen) = consumer.join().unwrap();
        assert_eq!(seen, n_threads * per_thread);
        assert!(max_batch_seen <= 16);
        b.close();
    }
}
