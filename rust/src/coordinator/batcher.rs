//! Dynamic batcher: size-or-deadline batching with a bounded queue.
//!
//! Requests accumulate in a FIFO; a worker receives a batch as soon as
//! either (a) `max_batch` requests are waiting, or (b) the oldest waiting
//! request has aged past `max_wait`.  The queue is bounded (`queue_cap`)
//! — submission fails fast when the system is saturated, which is the
//! backpressure contract the server surfaces to clients.

use super::protocol::{Request, Response};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
    pub queue_cap: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            max_batch: 32,
            max_wait: Duration::from_millis(2),
            queue_cap: 1024,
        }
    }
}

/// Where a finished [`Response`] is delivered.
pub enum ResponseSink {
    /// In-process caller blocked on an mpsc receiver.
    Channel(Sender<Response>),
    /// Reactor completion path: the response is tagged with the owning
    /// connection's token and the reactor is woken through its wake
    /// pipe — no per-request forwarder thread.
    #[cfg(target_os = "linux")]
    Reactor(super::net::CompletionSender),
}

impl ResponseSink {
    fn deliver(self, resp: Response) {
        match self {
            ResponseSink::Channel(tx) => {
                let _ = tx.send(resp);
            }
            #[cfg(target_os = "linux")]
            ResponseSink::Reactor(tx) => tx.send(resp),
        }
    }
}

/// Exactly-once response guard.  `send` consumes it; if it is dropped
/// without sending — worker panic, lane teardown with requests still
/// queued, a truncated engine result — it emits a `"worker dropped"`
/// error instead, so no accepted request is ever silently lost (the
/// seed's server ignored `rx.recv()` errors and lost exactly these).
pub struct Responder {
    id: u64,
    sink: Option<ResponseSink>,
}

impl Responder {
    pub fn new(id: u64, sink: ResponseSink) -> Self {
        Self { id, sink: Some(sink) }
    }

    /// The id of the request this responder answers.
    pub fn id(&self) -> u64 {
        self.id
    }

    pub fn send(mut self, resp: Response) {
        if let Some(sink) = self.sink.take() {
            sink.deliver(resp);
        }
    }
}

impl Drop for Responder {
    fn drop(&mut self) {
        if let Some(sink) = self.sink.take() {
            sink.deliver(Response::err(Some(self.id), "worker dropped"));
        }
    }
}

/// A queued request together with its response guard and enqueue time.
pub struct Pending {
    pub req: Request,
    pub enqueued: Instant,
    pub responder: Responder,
}

#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    QueueFull,
    Closed,
}

#[derive(Default)]
struct State {
    queue: VecDeque<Pending>,
}

/// Size-or-deadline dynamic batcher.
pub struct DynamicBatcher {
    cfg: BatcherConfig,
    state: Mutex<State>,
    cv: Condvar,
    closed: AtomicBool,
    pub submitted: AtomicU64,
    pub batches: AtomicU64,
}

impl DynamicBatcher {
    pub fn new(cfg: BatcherConfig) -> Self {
        Self {
            cfg,
            state: Mutex::new(State::default()),
            cv: Condvar::new(),
            closed: AtomicBool::new(false),
            submitted: AtomicU64::new(0),
            batches: AtomicU64::new(0),
        }
    }

    pub fn config(&self) -> &BatcherConfig {
        &self.cfg
    }

    /// Enqueue a request; fails fast on saturation or shutdown.  The
    /// `Pending` is handed back on failure so the caller can answer it
    /// with the right error (rather than the responder's generic
    /// worker-dropped message firing on drop).
    pub fn submit(&self, p: Pending) -> Result<(), (Pending, SubmitError)> {
        let mut st = self.state.lock().unwrap();
        // The closed check must happen under the state lock (and
        // `close` flips the flag under the same lock): otherwise a
        // submitter that passed a lock-free check could push AFTER a
        // dead lane's drain guard finished draining, stranding an
        // accepted request in a queue nothing will ever service.
        // ORDERING: Acquire pairs with close()'s Release store; both
        // run under the state lock (see above), the ordering only makes
        // the flag's publication explicit.
        if self.closed.load(Ordering::Acquire) {
            return Err((p, SubmitError::Closed));
        }
        if st.queue.len() >= self.cfg.queue_cap {
            return Err((p, SubmitError::QueueFull));
        }
        st.queue.push_back(p);
        // ORDERING: Relaxed — monotonic stat counter.
        self.submitted.fetch_add(1, Ordering::Relaxed);
        drop(st);
        self.cv.notify_one();
        Ok(())
    }

    pub fn depth(&self) -> usize {
        self.state.lock().unwrap().queue.len()
    }

    /// Block until a batch is ready (or `None` after close + drain).
    pub fn next_batch(&self) -> Option<Vec<Pending>> {
        let mut st = self.state.lock().unwrap();
        loop {
            if !st.queue.is_empty() {
                let ready_by_size = st.queue.len() >= self.cfg.max_batch;
                let oldest_age = st.queue.front().unwrap().enqueued.elapsed();
                let ready_by_age = oldest_age >= self.cfg.max_wait;
                if ready_by_size
                    || ready_by_age
                    // ORDERING: Acquire pairs with close()'s Release.
                    || self.closed.load(Ordering::Acquire)
                {
                    let n = st.queue.len().min(self.cfg.max_batch);
                    let batch: Vec<Pending> = st.queue.drain(..n).collect();
                    // ORDERING: Relaxed — monotonic stat counter.
                    self.batches.fetch_add(1, Ordering::Relaxed);
                    return Some(batch);
                }
                // Wait out the remaining age budget.
                let remaining = self.cfg.max_wait - oldest_age;
                let (g, _) = self.cv.wait_timeout(st, remaining).unwrap();
                st = g;
            } else {
                // ORDERING: Acquire pairs with close()'s Release.
                if self.closed.load(Ordering::Acquire) {
                    return None;
                }
                let (g, _) = self
                    .cv
                    .wait_timeout(st, Duration::from_millis(50))
                    .unwrap();
                st = g;
            }
        }
    }

    /// Stop accepting new work and wake all workers to drain.  The
    /// flag is flipped under the state lock so it serializes with
    /// `submit`: every accepted request is either visible to the final
    /// drain or rejected with `Closed` — never silently stranded.
    pub fn close(&self) {
        let st = self.state.lock().unwrap();
        // ORDERING: Release pairs with the Acquire loads in submit/
        // next_batch/is_closed; the state lock already serializes the
        // drain decision, the ordering publishes the flag itself.
        self.closed.store(true, Ordering::Release);
        drop(st);
        self.cv.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        // ORDERING: Acquire pairs with close()'s Release store.
        self.closed.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::BackendKind;
    use std::sync::mpsc::channel;
    use std::sync::Arc;

    fn mk_pending(id: u64) -> (Pending, std::sync::mpsc::Receiver<Response>) {
        let (tx, rx) = channel();
        (
            Pending {
                req: Request {
                    id,
                    model: "m".into(),
                    backend: BackendKind::Sketch,
                    features: vec![0.0],
                    want_scores: false,
                    update: None,
                },
                enqueued: Instant::now(),
                responder: Responder::new(id, ResponseSink::Channel(tx)),
            },
            rx,
        )
    }

    #[test]
    fn batch_forms_at_max_size() {
        let b = DynamicBatcher::new(BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_secs(10),
            queue_cap: 100,
        });
        let mut rxs = Vec::new();
        for i in 0..4 {
            let (p, rx) = mk_pending(i);
            assert!(b.submit(p).is_ok());
            rxs.push(rx);
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 4);
        // FIFO order preserved
        let ids: Vec<u64> = batch.iter().map(|p| p.req.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn batch_fires_on_deadline() {
        let b = DynamicBatcher::new(BatcherConfig {
            max_batch: 100,
            max_wait: Duration::from_millis(5),
            queue_cap: 100,
        });
        let (p, _rx) = mk_pending(1);
        let t0 = Instant::now();
        assert!(b.submit(p).is_ok());
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        let waited = t0.elapsed();
        assert!(waited >= Duration::from_millis(4), "{waited:?}");
        assert!(waited < Duration::from_millis(500), "{waited:?}");
    }

    #[test]
    fn queue_cap_enforced() {
        let b = DynamicBatcher::new(BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_secs(10),
            queue_cap: 2,
        });
        let (p1, _r1) = mk_pending(1);
        let (p2, _r2) = mk_pending(2);
        let (p3, _r3) = mk_pending(3);
        assert!(b.submit(p1).is_ok());
        assert!(b.submit(p2).is_ok());
        assert_eq!(b.submit(p3).unwrap_err().1, SubmitError::QueueFull);
    }

    #[test]
    fn close_rejects_and_drains() {
        let b = DynamicBatcher::new(BatcherConfig::default());
        let (p, _r) = mk_pending(1);
        assert!(b.submit(p).is_ok());
        b.close();
        let (p2, _r2) = mk_pending(2);
        assert_eq!(b.submit(p2).unwrap_err().1, SubmitError::Closed);
        // drain remaining then None
        assert_eq!(b.next_batch().unwrap().len(), 1);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn concurrent_producers_no_loss() {
        let b = Arc::new(DynamicBatcher::new(BatcherConfig {
            max_batch: 16,
            max_wait: Duration::from_millis(1),
            queue_cap: 100_000,
        }));
        let n_threads = 4;
        let per_thread = 500;
        let mut handles = Vec::new();
        for t in 0..n_threads {
            let b = b.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..per_thread {
                    let (p, _rx) = mk_pending((t * per_thread + i) as u64);
                    assert!(b.submit(p).is_ok());
                }
            }));
        }
        let consumer = {
            let b = b.clone();
            std::thread::spawn(move || {
                let mut seen = std::collections::HashSet::new();
                let mut max_batch_seen = 0;
                while seen.len() < n_threads * per_thread {
                    if let Some(batch) = b.next_batch() {
                        max_batch_seen = max_batch_seen.max(batch.len());
                        for p in batch {
                            assert!(seen.insert(p.req.id), "dup {}", p.req.id);
                        }
                    }
                }
                (seen.len(), max_batch_seen)
            })
        };
        for h in handles {
            h.join().unwrap();
        }
        let (seen, max_batch_seen) = consumer.join().unwrap();
        assert_eq!(seen, n_threads * per_thread);
        assert!(max_batch_seen <= 16);
        b.close();
    }
}
