//! Persistent sharded worker pool for batch-shard execution.
//!
//! The engines used to fan large batches out with a per-batch
//! `std::thread::scope` — every drained batch paid thread spawn/join
//! plus a cold `BatchScratch` allocation per worker.  This pool replaces
//! that with **long-lived** workers:
//!
//! * one OS thread per shard, spawned once and reused for every batch
//!   (the process-wide [`WorkerPool::shared`] instance is what the
//!   sketch, exact-kernel, and multiclass engines submit to);
//! * one channel-fed job queue per worker ("sharded" — no contended
//!   shared queue on the handoff path).  [`WorkerPool::run_jobs`]
//!   reserves a contiguous run of shard indices per batch, so one
//!   batch's shards always land on distinct workers; queues are FIFO,
//!   so under concurrent lanes a shard can still wait behind another
//!   lane's earlier shard on the same worker (the trade-off for
//!   queue-per-worker handoff);
//! * a per-worker [`WorkerScratch`] (batch + scalar + fused query
//!   scratch) owned by the worker thread and lent to every job it runs,
//!   so shard execution is allocation-free once warm.
//!
//! Jobs own their inputs (engines stage each shard's rows into an owned
//! buffer and `Arc`-share the model), so no scoped-lifetime tricks or
//! unsafe are needed; [`WorkerPool::run_jobs`] blocks until every shard
//! of the submitting batch has reported back, which preserves the
//! engines' synchronous `eval_batch` contract.  Workers are immortal: a
//! panicking job is caught, and `run_jobs` re-raises the panic on the
//! *submitting* thread (the same semantics the old per-batch
//! `std::thread::scope` fan-out had), so one bad request cannot kill a
//! shared worker out from under every other lane.

use crate::shard::ShardScratch;
use crate::sketch::{BatchScratch, FusedScratch, QuantScratch,
                    QueryScratch};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};

/// Per-worker reusable scratch, lent to every job the worker executes.
#[derive(Default)]
pub struct WorkerScratch {
    /// Batch-major sketch kernel scratch.
    pub batch: BatchScratch,
    /// Scalar query scratch (exact-kernel shards and friends).
    pub query: QueryScratch,
    /// Fused multiclass kernel scratch.
    pub fused: FusedScratch,
    /// Sharded-sketch shard kernel scratch (`sh` lane).
    pub shard: ShardScratch,
    /// Quantized-plane kernel scratch (quantized `rs`/`mc` lanes).
    pub quant: QuantScratch,
}

type Job = Box<dyn FnOnce(&mut WorkerScratch) + Send + 'static>;

/// Fixed-size pool of long-lived worker threads with per-worker job
/// queues and scratch.
pub struct WorkerPool {
    /// One job queue per worker; `Sender` kept behind a `Mutex` so the
    /// pool is `Sync` without relying on `Sender: Sync`.
    shards: Vec<Mutex<Sender<Job>>>,
    /// Round-robin cursor over the shards.
    next: AtomicUsize,
    /// Jobs completed across all workers (observability + tests).
    executed: Arc<AtomicUsize>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl WorkerPool {
    /// Spawn `n_workers` (at least 1) long-lived workers.
    pub fn new(n_workers: usize) -> Self {
        let n = n_workers.max(1);
        let executed = Arc::new(AtomicUsize::new(0));
        let mut shards = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for w in 0..n {
            let (tx, rx) = channel::<Job>();
            let handle = std::thread::Builder::new()
                .name(format!("pool-{w}"))
                .spawn(move || {
                    let mut scratch = WorkerScratch::default();
                    while let Ok(job) = rx.recv() {
                        // Workers are immortal: `run_jobs` wrappers
                        // catch and forward job panics, and this last
                        // line of defense keeps the invariant local.
                        let _ = std::panic::catch_unwind(
                            std::panic::AssertUnwindSafe(|| {
                                job(&mut scratch)
                            }),
                        );
                    }
                })
                // PANIC: construction-time only (never on the batch
                // hot path); failing to spawn a pool worker leaves the
                // process unable to serve at all.
                .expect("spawn pool worker");
            shards.push(Mutex::new(tx));
            handles.push(handle);
        }
        Self {
            shards,
            next: AtomicUsize::new(0),
            executed,
            handles: Mutex::new(handles),
        }
    }

    /// The process-wide pool shared by every engine (sized to the
    /// machine).  Its workers live for the life of the process.  Only
    /// hit at engine construction, never on the batch hot path.
    pub fn shared() -> Arc<WorkerPool> {
        static SHARED: Mutex<Option<Arc<WorkerPool>>> = Mutex::new(None);
        // PANIC: poisoned only if a constructor panicked while
        // holding it, which already tears the process down.
        let mut slot = SHARED.lock().unwrap();
        if let Some(pool) = slot.as_ref() {
            return pool.clone();
        }
        let cores = std::thread::available_parallelism()
            .map(|c| c.get())
            .unwrap_or(1);
        let pool = Arc::new(WorkerPool::new(cores));
        *slot = Some(pool.clone());
        pool
    }

    /// Number of worker threads (fixed at construction — the pool never
    /// spawns on the submission path).
    pub fn workers(&self) -> usize {
        self.shards.len()
    }

    /// Total shard jobs completed through [`WorkerPool::run_jobs`].  By
    /// the time a `run_jobs` call returns, every one of its shards is
    /// counted (the increment happens-before the shard's result send).
    pub fn jobs_executed(&self) -> usize {
        // ORDERING: Relaxed — the channel recv in run_jobs is the
        // happens-before edge; this read is a stat snapshot.
        self.executed.load(Ordering::Relaxed)
    }

    fn send_to(&self, shard: usize, job: Job) {
        self.shards[shard % self.shards.len()]
            .lock()
            // PANIC: sender mutex is only held across a send, which
            // does not panic — it cannot be poisoned.
            .unwrap()
            .send(job)
            // PANIC: workers are immortal by construction (they catch
            // job panics); a dead receiver means the invariant is
            // already broken and continuing would hang the caller.
            .expect("pool worker alive");
    }

    /// Run a batch's shard jobs and block until all complete; results
    /// come back in submission order.  This is the engines' fan-out
    /// primitive: shard i's result lands in slot i regardless of which
    /// worker ran it or in what order shards finished.  The batch
    /// reserves a contiguous run of shard indices, so its jobs land on
    /// distinct workers whenever `jobs.len() <= workers()`.  A panicking
    /// job is re-raised here, on the submitting thread.
    pub fn run_jobs<T, F>(&self, jobs: Vec<F>) -> Vec<T>
    where
        T: Send + 'static,
        F: FnOnce(&mut WorkerScratch) -> T + Send + 'static,
    {
        let n = jobs.len();
        let (tx, rx) = channel::<(usize, std::thread::Result<T>)>();
        // ORDERING: Relaxed — round-robin cursor; only atomicity of
        // the reservation matters.
        let start = self.next.fetch_add(n, Ordering::Relaxed);
        for (i, f) in jobs.into_iter().enumerate() {
            let tx = tx.clone();
            let executed = self.executed.clone();
            self.send_to(
                start.wrapping_add(i),
                Box::new(move |ws: &mut WorkerScratch| {
                    let r = std::panic::catch_unwind(
                        std::panic::AssertUnwindSafe(|| f(ws)),
                    );
                    // ORDERING: Relaxed — the result send below is
                    // the synchronizing edge; see jobs_executed.
                    executed.fetch_add(1, Ordering::Relaxed);
                    let _ = tx.send((i, r));
                }),
            );
        }
        drop(tx);
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            // PANIC: every job sends exactly once (panics are caught
            // and forwarded as Err), so n sends always arrive.
            let (i, r) = rx.recv().expect("pool shard completed");
            match r {
                Ok(v) => out[i] = Some(v),
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
        // PANIC: the loop above filled every slot or resumed unwind.
        out.into_iter().map(|o| o.expect("shard slot filled")).collect()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Closing the queues ends each worker's recv loop; join so no
        // worker outlives the pool (the `shared()` pool is never
        // dropped, so its workers persist for the process lifetime).
        self.shards.clear();
        // PANIC: handles mutex is only held here and at push time in
        // new(); neither panics while holding it.
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::thread::ThreadId;

    #[test]
    fn results_in_submission_order() {
        let pool = WorkerPool::new(4);
        let jobs: Vec<_> = (0..32)
            .map(|i| move |_ws: &mut WorkerScratch| i * 10)
            .collect();
        let out = pool.run_jobs(jobs);
        assert_eq!(out, (0..32).map(|i| i * 10).collect::<Vec<_>>());
        assert_eq!(pool.jobs_executed(), 32);
    }

    #[test]
    fn threads_are_reused_across_batches_never_spawned_per_batch() {
        // The no-per-batch-spawn contract: across many batches, every
        // job runs on one of the SAME `workers()` threads.
        let pool = WorkerPool::new(3);
        assert_eq!(pool.workers(), 3);
        let mut seen: HashSet<ThreadId> = HashSet::new();
        for _batch in 0..20 {
            let jobs: Vec<_> = (0..6)
                .map(|_| {
                    |_ws: &mut WorkerScratch| std::thread::current().id()
                })
                .collect();
            for id in pool.run_jobs(jobs) {
                seen.insert(id);
            }
        }
        assert!(
            seen.len() <= 3,
            "120 jobs must run on at most 3 long-lived threads, saw {}",
            seen.len()
        );
        assert_eq!(pool.jobs_executed(), 120);
    }

    #[test]
    fn scratch_persists_per_worker() {
        // Each worker lends the SAME scratch to successive jobs: warm a
        // buffer in round 1, observe the warm capacity in round 2.
        let pool = WorkerPool::new(1);
        let warm: Vec<_> = (0..1)
            .map(|_| {
                |ws: &mut WorkerScratch| {
                    ws.query.scores.resize(777, 0.0);
                }
            })
            .collect();
        pool.run_jobs(warm);
        let probe: Vec<_> = (0..1)
            .map(|_| |ws: &mut WorkerScratch| ws.query.scores.len())
            .collect();
        let got = pool.run_jobs(probe);
        assert_eq!(got, vec![777]);
    }

    #[test]
    fn zero_requested_workers_clamps_to_one() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.workers(), 1);
        let out =
            pool.run_jobs(vec![|_ws: &mut WorkerScratch| 42usize]);
        assert_eq!(out, vec![42]);
    }

    #[test]
    fn panicking_job_reraises_on_submitter_and_workers_survive() {
        let pool = WorkerPool::new(2);
        let boom = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                pool.run_jobs(vec![
                    |_ws: &mut WorkerScratch| -> usize {
                        panic!("shard boom")
                    },
                ]);
            }),
        );
        assert!(boom.is_err(), "panic must surface on the submitter");
        // The long-lived workers survived; later batches run normally.
        let jobs: Vec<_> = (1..3usize)
            .map(|i| move |_ws: &mut WorkerScratch| i)
            .collect();
        assert_eq!(pool.run_jobs(jobs), vec![1, 2]);
    }

    #[test]
    fn shared_pool_is_one_instance() {
        let a = WorkerPool::shared();
        let b = WorkerPool::shared();
        assert!(Arc::ptr_eq(&a, &b));
        assert!(a.workers() >= 1);
    }

    #[test]
    fn concurrent_submitters_all_complete() {
        let pool = Arc::new(WorkerPool::new(4));
        let mut handles = Vec::new();
        for t in 0..6u64 {
            let pool = pool.clone();
            handles.push(std::thread::spawn(move || {
                let jobs: Vec<_> = (0..50u64)
                    .map(|i| move |_ws: &mut WorkerScratch| t * 1000 + i)
                    .collect();
                pool.run_jobs(jobs)
            }));
        }
        for (t, h) in handles.into_iter().enumerate() {
            let got = h.join().unwrap();
            for (i, v) in got.into_iter().enumerate() {
                assert_eq!(v, t as u64 * 1000 + i as u64);
            }
        }
        assert_eq!(pool.jobs_executed(), 300);
    }
}
