//! L3 serving coordinator — the deployment wrapper around the sketch and
//! its baselines: request router, dynamic batcher, backend engines,
//! epoll-reactor TCP front-end, metrics, and bounded-queue backpressure.
//!
//! Architecture (vLLM-router-shaped, scaled to an edge-inference system):
//!
//! ```text
//!        TCP clients                     in-process clients
//!             │                                  │
//!             ▼                                  │
//!      ┌─────────────┐ submit_sink(Request)      │ submit(Request)
//!      │   Reactor   ├──────────┐                │
//!      │ (ONE epoll  │          ▼                ▼
//!      │   thread)   │     ┌─────────┐    per-(model, backend)
//!      └──────▲──────┘     │ Router  ├──► ┌──────────────┐
//!             │ wake pipe  └─────────┘    │ DynamicBatch │──► lane
//!             │ + completion channel      │  (size/age)  │    worker
//!             └───────────────────────────┴──────────────┘      │
//!                                                               ▼
//!                                                    Engine (RS hot path /
//!                                                    rust NN / PJRT), pool
//! ```
//!
//! Python is never on this path; the PJRT backends execute AOT artifacts.
//!
//! **Thread accounting invariant:** the serving process runs exactly
//! ONE reactor thread, one worker thread per registered lane, and the
//! fixed `pool::WorkerPool` threads.  Nothing on the accept, request,
//! or completion path spawns — lane workers hand finished responses to
//! the reactor over an mpsc channel and poke its wake pipe, and the
//! reactor multiplexes every connection through epoll with incremental
//! line framing (hard per-line byte cap — a newline-free stream is
//! rejected, not buffered) and buffered nonblocking writes.  The seed's
//! front-end spawned a thread per connection *and* per in-flight
//! request; that loop survived one release as `--threads-legacy` and is
//! now gone from Linux builds entirely (a thread-per-connection
//! fallback remains on non-Linux targets only, where there is no
//! epoll).
//!
//! **Response delivery invariant:** every accepted request produces
//! exactly one [`Response`].  Each request carries a
//! [`batcher::Responder`] whose drop guard answers `"worker dropped"`
//! if a lane dies mid-flight; malformed lines are answered with a
//! best-effort-recovered id (else `"id": null`, never a fake id 0);
//! backpressure rejections echo the request id.
//!
//! Batching is end-to-end: a drained `DynamicBatcher` batch reaches the
//! engine as ONE `eval_batch` call over feature vectors *moved* out of
//! the requests (zero per-request allocations on the hot path), and the
//! sketch / exact-kernel / multiclass engines execute it through the
//! batch-major kernels (`RaceSketch::query_batch_with`,
//! `FusedMultiSketch::scores_batch_with` — a single CSC hash walk
//! serving the whole batch).  Large batches are sharded across the
//! persistent `pool::WorkerPool`.  The batched path is bit-identical to
//! the scalar path, so batch size and shard count are pure throughput
//! knobs, never correctness knobs.
//!
//! The `sh` lane (`backend::ShardedEngine`) additionally shards the
//! MODEL: the sketch's repetitions are partitioned into whole
//! median-of-means groups per `crate::shard::SketchShard`, every
//! drained batch fans out as exactly one shard-kernel submission per
//! shard through the pool, and the partial group means are merged
//! estimator-exactly on the lane thread — bit-identical to the
//! monolithic lanes at any shard count.  Multiclass lanes (`mc`, `sh`)
//! answer argmax class indices and, per request (`"scores": true`),
//! the full per-class score vector.
//!
//! **The remote shard plane** lifts those shard kernels into separate
//! processes/hosts with the SAME exact-merge contract: the reactor is
//! generic over a [`net::LineHandler`], so `repsketch shard-serve`
//! runs one `crate::shard::remote::ShardService` (reactor + one kernel
//! worker, fixed threads) behind `Server::bind_handler`, and
//! `backend::RemoteShardedEngine` (`serve --sharded-remote`) projects
//! a drained batch once on the lane thread, scatters ONE request per
//! persistent pipelined shard connection (driving the sockets itself —
//! nothing on the batch path spawns), gathers the complete group
//! means, and runs the untouched merge — bit-for-bit identical to the
//! local `sh` lane.  The exactly-one-response guarantee extends across
//! the wire: a killed, stalled (timeout), or misbehaving shard fails
//! the batch with an error NAMING that shard — the router answers
//! every in-flight request, never silence and never a partial merge —
//! and the next batch reconnects and re-validates the handshake, so a
//! restarted shard is picked up transparently.  Capacity then scales
//! by adding shard processes, not cores
//! (`tests/remote_shard.rs` locks the fault model; the bit-identity is
//! property-tested there too).
//!
//! # Operating the replicated shard plane
//!
//! `serve --sharded-remote NAME=a0|a1,b0|b1` registers shard replica
//! GROUPS: comma-separated shards in shard-index order, `|`-separated
//! replica addresses within a shard (all serving the same RSFS file,
//! which is why replication can never change an answer).  Per batch,
//! each shard's request goes to its least-loaded healthy replica; a
//! straggler is hedged to a second replica after an adaptive deadline
//! seeded from observed latency; a replica that dies mid-gather fails
//! over in-batch under the same request id (first valid answer wins,
//! late duplicates are discarded by id); failed replicas are
//! quarantined and re-probed with capped exponential backoff + jitter.
//! `--remote-timeout-ms` is the hard per-batch deadline and
//! `--hedge-ms` the pre-sample hedge delay (see
//! `shard::RemoteOptions`).
//!
//! ## The `stats` wire verb
//!
//! `{"id": N, "stats": true}` on the inference plane answers one line:
//!
//! ```text
//! {"id": N, "stats": {
//!    "rejected": <backpressure rejections>,
//!    "lanes":  [{"model", "backend", "v", "submitted", "batches",
//!                "ok", "errors", "latency": {n, mean_us, p50_us,
//!                p99_us, p999_us},
//!                "update": null | {"epoch", "updates", "publishes",
//!                                  "pending", "staleness_us"}}, ...],
//!    "shards": [{"model", "shards": [{"shard", "gathers", "errors",
//!                "hedges", "failovers", "reconnects", "quarantines",
//!                "discarded", "latency": {...},
//!                "replicas": [{"addr", "sent", "answered",
//!                              "abandoned", "ewma_us"}, ...]}, ...]}]
//! }}
//! ```
//!
//! Shard servers answer the same verb with their own serve counters.
//! All counters are monotonic for the process lifetime; operators diff
//! successive snapshots for windowed rates.  The **error budget** for
//! an availability target `t` (e.g. `0.999`) over a window is
//! `(ok + errors) × (1 − t) − errors` — how many more errors the lane
//! may serve before the objective is violated (negative = blown); see
//! `metrics::slo` for the convention.
//!
//! # Live updates, hot swap, and drain
//!
//! The serving plane mutates under load through two verbs with
//! different blast radii:
//!
//! **`update`** mutates the CURRENT model in place: `{"id": N,
//! "model": "m", "backend": "rs", "features": [p floats], "update":
//! {"weight": w, "class": c, "delete": false, "publish": false}}`
//! folds a weighted point (projected space) into the lane's
//! double-buffered [`crate::sketch::epoch::CounterPlane`] — a delete is
//! the same fold with `-w`, which is exact for a linear sketch.
//! Queries PIN an epoch and read a consistent snapshot; updates land in
//! the shadow buffer and become visible at the next **publish**
//! (explicit `"publish": true`, or forced when the shadow backlog
//! reaches the plane's bound — see
//! [`crate::sketch::epoch::MAX_PENDING`]).  That bound is the
//! staleness guarantee: a reader's snapshot is never more than
//! `MAX_PENDING` updates behind, per plane (per shard on `sh` lanes).
//! Current staleness is surfaced as `update.staleness_us` (age of the
//! oldest unpublished delta) and `update.pending` in the stats line.
//! Updates and queries stay FIFO on a lane, so an acked update is
//! visible to every later query from the same connection
//! (read-your-writes); the ack carries the publish epoch.  On
//! remote-sharded lanes the update broadcasts to every replica of
//! every shard, and a replica whose applied-update count (`seq`)
//! diverges is quarantined rather than allowed to serve from a
//! different history.
//!
//! **`swap`** replaces the WHOLE model atomically: `{"id": N, "swap":
//! {"model": "m", "backend": "rs", "path": "new.rssk", "shards": 0}}`
//! loads + validates the named RSSK/RSFM/RSFS set on a dedicated admin
//! thread (the one documented exception to the thread-accounting
//! invariant — it lives only while a swap is in flight, and load IO
//! never touches the reactor), then flips the lane pointer under the
//! router's lane map and drains the old lane through the same path
//! `add_lane` replacement and shutdown use: the old batcher closes,
//! its worker answers everything already queued ON THE OLD MODEL, and
//! the thread is joined.  A failed load answers an error and never
//! flips.  **Version attribution:** every lane response carries `"v"`,
//! the monotone version assigned at registration — during a swap each
//! response is attributable to exactly one of the two versions, with
//! zero dropped or duplicated requests (locked by
//! `tests/live_update.rs`).
//!
//! **Drain** is the shared shutdown primitive: lane replacement (swap),
//! `Router::shutdown`, and SIGTERM/SIGINT (installed by `serve` /
//! `shard-serve` via `net::sys::install_stop_signals`) all close the
//! batcher(s), let the worker(s) answer every queued request, and join
//! — so a `kill` exits 0 with zero stranded clients.

pub mod backend;
pub mod batcher;
#[cfg(target_os = "linux")]
pub mod net;
pub mod pool;
pub mod protocol;
pub mod router;
pub mod server;

pub use backend::{BackendKind, BatchOutput, Engine, ScoreMatrix};
pub use batcher::{
    BatcherConfig, DynamicBatcher, Responder, ResponseSink,
};
pub use pool::{WorkerPool, WorkerScratch};
pub use protocol::{extract_id, Request, Response};
pub use router::{Router, RouterConfig, SubmitError};
pub use server::{ServeMode, Server};
