//! L3 serving coordinator — the deployment wrapper around the sketch and
//! its baselines: request router, dynamic batcher, backend engines, TCP
//! JSON-line server, metrics, and bounded-queue backpressure.
//!
//! Architecture (vLLM-router-shaped, scaled to an edge-inference system):
//!
//! ```text
//!        TCP / in-process clients
//!                 │  submit(Request)
//!                 ▼
//!            ┌─────────┐    per-(model, backend) bounded queues
//!            │ Router  ├──► ┌──────────────┐
//!            └─────────┘    │ DynamicBatch │──► worker thread ──► Engine
//!                           │  (size/age)  │        │ (RS hot path /
//!                           └──────────────┘        │  rust NN / PJRT)
//!                                                   ▼
//!                                          per-request responses
//! ```
//!
//! Python is never on this path; the PJRT backends execute AOT artifacts.
//!
//! Batching is end-to-end: a drained `DynamicBatcher` batch reaches the
//! engine as ONE `eval_batch` call, and the sketch / exact-kernel /
//! multiclass engines execute it through the batch-major kernels
//! (`RaceSketch::query_batch_with`, `FusedMultiSketch::predict_batch_with`
//! — a single CSC hash walk serving the whole batch).  Large batches are
//! sharded across the **persistent worker pool** (`pool::WorkerPool` —
//! long-lived threads, channel-fed shard queues, per-worker scratch;
//! nothing on the hot path spawns a thread).  The batched path is
//! bit-identical to the scalar path, so batch size and shard count are
//! pure throughput knobs, never correctness knobs.

pub mod backend;
pub mod batcher;
pub mod pool;
pub mod protocol;
pub mod router;
pub mod server;

pub use backend::{BackendKind, Engine};
pub use batcher::{BatcherConfig, DynamicBatcher};
pub use pool::{WorkerPool, WorkerScratch};
pub use protocol::{Request, Response};
pub use router::{Router, RouterConfig, SubmitError};
pub use server::Server;
