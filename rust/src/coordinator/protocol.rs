//! Wire/request types for the coordinator and the TCP JSON-line protocol.
//!
//! One request per line:
//! `{"id": 7, "model": "adult", "backend": "rs", "x": [..d floats..]}`
//! One response per line:
//! `{"id": 7, "y": 0.42, "us": 13.5}` or `{"id": 7, "error": "..."}`.
//!
//! Multiclass lanes (`"mc"`, `"sh"`) answer the argmax class index in
//! `"y"`.  A request may additionally set `"scores": true` to receive
//! the full per-class score vector alongside the argmax:
//! `{"id": 7, "y": 2, "scores": [..C floats..], "us": 13.5}`.  The flag
//! is per-request (a batch mixes both kinds freely) and ignored by
//! single-output engines, which carry no score vector.
//!
//! A line of the form `{"id": 7, "stats": true}` is NOT an inference
//! request: it asks the coordinator for its SLO counters (see
//! `Router::stats_line` for the response schema) and is answered
//! inline, without touching any lane.
//!
//! Two mutation verbs ride the same line protocol:
//!
//! * **update** — `{"id": 7, "model": "adult", "backend": "rs",
//!   "x": [..p floats..], "update": {"weight": 1.0, "class": 0,
//!   "delete": false, "publish": false}}` streams one weighted point
//!   into the lane's live counter plane (`x` is in the PROJECTED space,
//!   like the build points — updates mutate the representer set, not the
//!   query side).  Every `"update"` sub-field is optional (`weight` 1.0,
//!   `class` 0, `delete`/`publish` false); `delete` negates the weight.
//!   The ack is `{"id": 7, "epoch": E, "y": 0, "us": ..., "v": V}` —
//!   `epoch` is the plane's published epoch after the update batch
//!   (updates stay FIFO-ordered with queries on the lane, so a later
//!   query on the same connection always sees this update).
//! * **swap** — `{"id": 9, "swap": {"model": "adult", "backend": "rs",
//!   "path": "models/adult_v2.rssk", "shards": 4}}` atomically replaces
//!   a whole model: load + validate the new RSSK/RSFM/RSFS set, flip
//!   the lane, drain the old one.  Answered by
//!   `{"id": 9, "swapped": {...,"v": V}}` or an error (a failed load
//!   never flips).  `shards` is only for `"sh"` lanes (0 = RSFS
//!   shard-set prefix on disk).
//!
//! Every lane response carries `"v"`, the monotonically increasing lane
//! version assigned at `add_lane`/swap time — the version-attribution
//! handle: any response is the output of exactly one model version.

use super::backend::BackendKind;
use crate::util::json::{self, Json};

/// The mutation rider of an `update` request (see module docs).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct UpdateSpec {
    /// Weight of the streamed point (α contribution).
    pub weight: f32,
    /// Target class (fused lanes; 0 for single-output sketches).
    pub class: usize,
    /// Delete: fold `-weight` instead of `+weight`.
    pub delete: bool,
    /// Force an epoch publish after this batch of updates.
    pub publish: bool,
}

impl UpdateSpec {
    /// The signed α this update folds into the plane.
    pub fn alpha(&self) -> f32 {
        if self.delete { -self.weight } else { self.weight }
    }
}

/// An inference request routed through the coordinator.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub model: String,
    pub backend: BackendKind,
    pub features: Vec<f32>,
    /// Ask a multiclass lane for the full per-class score vector in
    /// addition to the argmax (`"scores": true` on the wire).
    pub want_scores: bool,
    /// Present => this is a mutation, not a query: `features` is the
    /// point to fold into the lane's live counter plane.
    pub update: Option<UpdateSpec>,
}

/// The coordinator's answer.
///
/// `id` is `None` only for protocol-level errors where the offending
/// line carried no recoverable id — emitted as `"id": null` so it can
/// never collide with a legitimate request id (the seed hard-coded 0,
/// which a real request may also use).
#[derive(Clone, Debug)]
pub struct Response {
    pub id: Option<u64>,
    pub result: Result<f32, String>,
    /// Per-class scores, present only when the request set
    /// `want_scores` and the lane's engine produces score vectors.
    pub scores: Option<Vec<f32>>,
    /// Queue + execution latency in microseconds.
    pub latency_us: f64,
    /// Update acks: the counter plane's published epoch after the
    /// update batch (`"epoch"` on the wire).
    pub epoch: Option<u64>,
    /// The lane version that produced this response (`"v"` on the
    /// wire) — the version-attribution handle across hot-swaps.
    pub version: Option<u64>,
}

impl Request {
    pub fn parse_line(line: &str) -> Result<Request, String> {
        let j = json::parse(line)?;
        let id = j
            .get("id")
            .and_then(|v| v.as_u64())
            .ok_or("missing/invalid id")?;
        let model = j
            .get("model")
            .and_then(|v| v.as_str())
            .ok_or("missing model")?
            .to_string();
        let backend = match j.get("backend").and_then(|v| v.as_str()) {
            Some(s) => BackendKind::parse(s).ok_or("unknown backend")?,
            None => BackendKind::Sketch,
        };
        let features = j.get("x").ok_or("missing x")?.as_f32_flat();
        if features.is_empty() {
            return Err("empty feature vector".into());
        }
        let want_scores =
            j.get("scores").and_then(|v| v.as_bool()).unwrap_or(false);
        let update = match j.get("update") {
            None => None,
            Some(u) => {
                let weight = match u.get("weight") {
                    None => 1.0f32,
                    Some(w) => {
                        // CAST: protocol weights are f32 payloads;
                        // f64 -> f32 rounds to nearest, finiteness is
                        // checked on the next line.
                        let w = w.as_f64().ok_or("invalid update weight")?
                            as f32; // CAST: see above
                        if !w.is_finite() {
                            return Err("non-finite update weight".into());
                        }
                        w
                    }
                };
                let class = match u.get("class") {
                    None => 0usize,
                    Some(c) => c.as_usize().ok_or("invalid update class")?,
                };
                let delete = u
                    .get("delete")
                    .and_then(|v| v.as_bool())
                    .unwrap_or(false);
                let publish = u
                    .get("publish")
                    .and_then(|v| v.as_bool())
                    .unwrap_or(false);
                Some(UpdateSpec { weight, class, delete, publish })
            }
        };
        Ok(Request { id, model, backend, features, want_scores, update })
    }

    pub fn to_line(&self) -> String {
        let x = Json::Arr(
            self.features.iter().map(|&v| Json::num_f32(v)).collect(),
        );
        let mut pairs = vec![
            ("id", Json::from_u64(self.id)),
            ("model", Json::Str(self.model.clone())),
            ("backend", Json::Str(self.backend.name().into())),
            ("x", x),
        ];
        if self.want_scores {
            pairs.push(("scores", Json::Bool(true)));
        }
        if let Some(u) = &self.update {
            pairs.push((
                "update",
                json::obj(vec![
                    ("weight", Json::num_f32(u.weight)),
                    // CAST: usize -> u64 widens on every supported
                    // target (64-bit and 32-bit).
                    ("class", Json::from_u64(u.class as u64)),
                    ("delete", Json::Bool(u.delete)),
                    ("publish", Json::Bool(u.publish)),
                ]),
            ));
        }
        json::obj(pairs).to_string()
    }
}

impl Response {
    /// Error response — the shape every rejection/failure path shares
    /// (no scores, zero latency).  Centralized so protocol growth does
    /// not mean hand-editing a dozen error literals again.
    pub fn err(id: Option<u64>, msg: impl Into<String>) -> Response {
        Response {
            id,
            result: Err(msg.into()),
            scores: None,
            latency_us: 0.0,
            epoch: None,
            version: None,
        }
    }

    fn id_json(&self) -> Json {
        match self.id {
            Some(id) => Json::from_u64(id),
            None => Json::Null,
        }
    }

    pub fn to_line(&self) -> String {
        match &self.result {
            Ok(y) => {
                // f32 payloads ship as shortest-f32 decimals (exact
                // round-trip, ~half the bytes of the f64 form).
                let mut pairs = vec![
                    ("id", self.id_json()),
                    ("y", Json::num_f32(*y)),
                ];
                if let Some(scores) = &self.scores {
                    pairs.push((
                        "scores",
                        Json::Arr(
                            scores
                                .iter()
                                .map(|&v| Json::num_f32(v))
                                .collect(),
                        ),
                    ));
                }
                if let Some(e) = self.epoch {
                    pairs.push(("epoch", Json::from_u64(e)));
                }
                pairs.push(("us", Json::num(self.latency_us)));
                if let Some(v) = self.version {
                    pairs.push(("v", Json::from_u64(v)));
                }
                json::obj(pairs).to_string()
            }
            Err(e) => {
                let mut pairs = vec![
                    ("id", self.id_json()),
                    ("error", Json::Str(e.clone())),
                ];
                if let Some(v) = self.version {
                    pairs.push(("v", Json::from_u64(v)));
                }
                json::obj(pairs).to_string()
            }
        }
    }

    pub fn parse_line(line: &str) -> Result<Response, String> {
        let j = json::parse(line)?;
        // `"id": null` (or a missing id) is legal on error responses.
        let id = j.get("id").and_then(|v| v.as_u64());
        let version = j.get("v").and_then(|v| v.as_u64());
        if let Some(err) = j.get("error").and_then(|v| v.as_str()) {
            return Ok(Response {
                id,
                result: Err(err.to_string()),
                scores: None,
                latency_us: 0.0,
                epoch: None,
                version,
            });
        }
        let id = Some(id.ok_or("missing id")?);
        let y = j
            .get("y")
            .and_then(|v| v.as_f64())
            // CAST: wire scores are f32 payloads; round to nearest.
            .ok_or("missing y")? as f32;
        let scores = j.get("scores").map(|v| v.as_f32_flat());
        let us = j.get("us").and_then(|v| v.as_f64()).unwrap_or(0.0);
        let epoch = j.get("epoch").and_then(|v| v.as_u64());
        Ok(Response { id, result: Ok(y), scores, latency_us: us, epoch,
                      version })
    }
}

/// Recognize a `{"id": N, "stats": true}` line — the stats verb.
/// Returns the request id, or `None` when the line is anything else
/// (including unparseable JSON: those fall through to the normal
/// request path and its error reporting).
pub fn parse_stats_line(line: &str) -> Option<u64> {
    let j = json::parse(line).ok()?;
    if j.get("stats").and_then(|v| v.as_bool()) != Some(true) {
        return None;
    }
    j.get("id").and_then(|v| v.as_u64())
}

/// The hot-swap admin verb's payload (see module docs).
#[derive(Clone, Debug, PartialEq)]
pub struct SwapSpec {
    /// Lane model name to replace (or create).
    pub model: String,
    /// Lane backend kind (`"rs"`, `"mc"`, `"sh"`).
    pub backend: BackendKind,
    /// Path of the new model: `.rssk`/`.rsfm` file for `rs`/`mc`/`sh`,
    /// or an RSFS shard-set prefix for `sh` with `shards == 0`.
    pub path: String,
    /// For `sh`: shard count to carve a monolithic file into (0 = load
    /// a pre-sharded `{path}.shard{i}.rsfs` set).  Ignored otherwise.
    pub shards: usize,
}

/// Recognize a `{"id": N, "swap": {...}}` line — the hot-swap verb.
/// Returns `None` when the line is anything else; `Some(Err(msg))` when
/// the `swap` key is present but its payload is invalid (the router
/// answers an error rather than misreading it as an inference request).
pub fn parse_swap_line(line: &str)
    -> Option<Result<(u64, SwapSpec), String>> {
    let j = json::parse(line).ok()?;
    let sw = j.get("swap")?;
    let parse = || -> Result<(u64, SwapSpec), String> {
        let id = j
            .get("id")
            .and_then(|v| v.as_u64())
            .ok_or("swap: missing/invalid id")?;
        let model = sw
            .get("model")
            .and_then(|v| v.as_str())
            .ok_or("swap: missing model")?
            .to_string();
        let backend = match sw.get("backend").and_then(|v| v.as_str()) {
            Some(s) => {
                BackendKind::parse(s).ok_or("swap: unknown backend")?
            }
            None => BackendKind::Sketch,
        };
        let path = sw
            .get("path")
            .and_then(|v| v.as_str())
            .ok_or("swap: missing path")?
            .to_string();
        if path.is_empty() {
            return Err("swap: empty path".into());
        }
        let shards = match sw.get("shards") {
            None => 0usize,
            Some(v) => v.as_usize().ok_or("swap: invalid shards")?,
        };
        Ok((id, SwapSpec { model, backend, path, shards }))
    };
    Some(parse())
}

/// Best-effort recovery of the `"id"` field from a line that failed
/// `Request::parse_line`, so the error response can still be correlated
/// by the client.  Tries a real JSON parse first (covers "valid JSON,
/// invalid request"), then falls back to a byte scan for `"id"`
/// followed by `:` and an unsigned integer (covers truncated or
/// otherwise malformed JSON).  Returns `None` when nothing usable is
/// found — the response then carries `"id": null`.
pub fn extract_id(line: &str) -> Option<u64> {
    if let Ok(j) = json::parse(line) {
        return j.get("id").and_then(|v| v.as_u64());
    }
    let b = line.as_bytes();
    let needle = b"\"id\"";
    let mut i = 0usize;
    while i + needle.len() <= b.len() {
        if &b[i..i + needle.len()] == needle {
            let mut j = i + needle.len();
            while j < b.len() && (b[j] == b' ' || b[j] == b'\t') {
                j += 1;
            }
            if j < b.len() && b[j] == b':' {
                j += 1;
                while j < b.len() && (b[j] == b' ' || b[j] == b'\t') {
                    j += 1;
                }
                let start = j;
                while j < b.len() && b[j].is_ascii_digit() {
                    j += 1;
                }
                if j > start {
                    if let Ok(v) =
                        std::str::from_utf8(&b[start..j]).unwrap().parse()
                    {
                        return Some(v);
                    }
                }
            }
        }
        i += 1;
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let r = Request {
            id: 42,
            model: "adult".into(),
            backend: BackendKind::NnRust,
            features: vec![1.0, -0.5, 0.0],
            want_scores: false,
            update: None,
        };
        let line = r.to_line();
        assert!(!line.contains("scores"), "{line}");
        let r2 = Request::parse_line(&line).unwrap();
        assert_eq!(r2.id, 42);
        assert_eq!(r2.model, "adult");
        assert_eq!(r2.backend, BackendKind::NnRust);
        assert_eq!(r2.features, r.features);
        assert!(!r2.want_scores);
    }

    #[test]
    fn scores_request_roundtrip() {
        let r = Request {
            id: 7,
            model: "digits".into(),
            backend: BackendKind::Sharded,
            features: vec![0.25, 1.0],
            want_scores: true,
            update: None,
        };
        let line = r.to_line();
        assert!(line.contains("\"scores\":true"), "{line}");
        let r2 = Request::parse_line(&line).unwrap();
        assert!(r2.want_scores);
        assert_eq!(r2.backend, BackendKind::Sharded);
        // "scores": false / absent both mean argmax-only.
        let r3 = Request::parse_line(
            r#"{"id":1,"model":"m","backend":"mc","x":[1],"scores":false}"#,
        )
        .unwrap();
        assert!(!r3.want_scores);
    }

    #[test]
    fn response_roundtrip() {
        let ok = Response {
            id: Some(1),
            result: Ok(0.5),
            scores: None,
            latency_us: 12.5,
            epoch: None,
            version: None,
        };
        let line = ok.to_line();
        assert!(!line.contains("scores"), "{line}");
        let p = Response::parse_line(&line).unwrap();
        assert_eq!(p.id, Some(1));
        assert_eq!(p.result.unwrap(), 0.5);
        assert!(p.scores.is_none());
        let err = Response {
            id: Some(2),
            result: Err("boom".into()),
            scores: None,
            latency_us: 0.0,
            epoch: None,
            version: None,
        };
        let p2 = Response::parse_line(&err.to_line()).unwrap();
        assert_eq!(p2.id, Some(2));
        assert!(p2.result.is_err());
    }

    #[test]
    fn scores_response_roundtrip() {
        let ok = Response {
            id: Some(9),
            result: Ok(2.0),
            scores: Some(vec![0.1, -0.25, 0.75]),
            latency_us: 3.5,
            epoch: None,
            version: None,
        };
        let line = ok.to_line();
        assert!(line.contains("\"scores\":["), "{line}");
        let p = Response::parse_line(&line).unwrap();
        assert_eq!(p.id, Some(9));
        assert_eq!(p.result.unwrap(), 2.0);
        assert_eq!(p.scores.unwrap(), vec![0.1, -0.25, 0.75]);
        assert_eq!(p.latency_us, 3.5);
    }

    #[test]
    fn null_id_error_roundtrips() {
        let err = Response {
            id: None,
            result: Err("bad request".into()),
            scores: None,
            latency_us: 0.0,
            epoch: None,
            version: None,
        };
        let line = err.to_line();
        assert!(line.contains("\"id\":null"), "{line}");
        let p = Response::parse_line(&line).unwrap();
        assert_eq!(p.id, None);
        assert!(p.result.is_err());
        // A null id on a *success* response stays invalid.
        assert!(Response::parse_line(r#"{"id":null,"y":1.0}"#).is_err());
    }

    #[test]
    fn extract_id_best_effort() {
        // Valid JSON, invalid request (missing model): JSON path.
        assert_eq!(extract_id(r#"{"id": 7, "x": [1]}"#), Some(7));
        // Malformed JSON: byte-scan path.
        assert_eq!(extract_id(r#"{"id": 42, "model": "#), Some(42));
        assert_eq!(extract_id(r#"{"x":[1],"id":3"#), Some(3));
        // Nothing recoverable.
        assert_eq!(extract_id("garbage"), None);
        assert_eq!(extract_id(r#"{"id": "seven"}"#), None);
        assert_eq!(extract_id(r#"{"id": -4}"#), None);
    }

    #[test]
    fn default_backend_is_sketch() {
        let r =
            Request::parse_line(r#"{"id":1,"model":"m","x":[1]}"#).unwrap();
        assert_eq!(r.backend, BackendKind::Sketch);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Request::parse_line("{}").is_err());
        assert!(Request::parse_line(r#"{"id":1,"model":"m","x":[]}"#)
            .is_err());
        assert!(Request::parse_line("not json").is_err());
    }

    #[test]
    fn update_request_roundtrip_and_defaults() {
        let r = Request {
            id: 11,
            model: "adult".into(),
            backend: BackendKind::Sketch,
            features: vec![0.5, -1.0],
            want_scores: false,
            update: Some(UpdateSpec {
                weight: 2.5,
                class: 3,
                delete: true,
                publish: true,
            }),
        };
        let line = r.to_line();
        assert!(line.contains("\"update\":{"), "{line}");
        let r2 = Request::parse_line(&line).unwrap();
        let u = r2.update.unwrap();
        assert_eq!(u, r.update.unwrap());
        assert_eq!(u.alpha(), -2.5);
        // Every update sub-field is optional.
        let r3 = Request::parse_line(
            r#"{"id":1,"model":"m","x":[1],"update":{}}"#,
        )
        .unwrap();
        let u3 = r3.update.unwrap();
        assert_eq!(
            u3,
            UpdateSpec { weight: 1.0, class: 0, delete: false,
                         publish: false }
        );
        assert_eq!(u3.alpha(), 1.0);
        // Absent "update" key => plain query.
        assert!(Request::parse_line(r#"{"id":1,"model":"m","x":[1]}"#)
            .unwrap()
            .update
            .is_none());
        // Malformed riders are rejected, not silently defaulted.
        assert!(Request::parse_line(
            r#"{"id":1,"model":"m","x":[1],"update":{"class":"a"}}"#
        )
        .is_err());
        assert!(Request::parse_line(
            r#"{"id":1,"model":"m","x":[1],"update":{"weight":"w"}}"#
        )
        .is_err());
    }

    #[test]
    fn response_epoch_and_version_roundtrip() {
        let ack = Response {
            id: Some(4),
            result: Ok(0.0),
            scores: None,
            latency_us: 1.5,
            epoch: Some(17),
            version: Some(3),
        };
        let line = ack.to_line();
        assert!(line.contains("\"epoch\":17"), "{line}");
        assert!(line.contains("\"v\":3"), "{line}");
        let p = Response::parse_line(&line).unwrap();
        assert_eq!(p.epoch, Some(17));
        assert_eq!(p.version, Some(3));
        // Errors can still be version-attributed.
        let e = Response {
            version: Some(9),
            ..Response::err(Some(5), "boom")
        };
        let p2 = Response::parse_line(&e.to_line()).unwrap();
        assert_eq!(p2.version, Some(9));
        assert!(p2.result.is_err());
        // Plain responses stay free of the new keys.
        let plain = Response {
            id: Some(1),
            result: Ok(1.0),
            scores: None,
            latency_us: 0.0,
            epoch: None,
            version: None,
        };
        let line = plain.to_line();
        assert!(!line.contains("epoch"), "{line}");
        assert!(!line.contains("\"v\""), "{line}");
    }

    #[test]
    fn swap_line_detection_and_validation() {
        let got = parse_swap_line(
            r#"{"id":3,"swap":{"model":"adult","backend":"mc",
                "path":"m.rsfm","shards":2}}"#,
        )
        .unwrap()
        .unwrap();
        assert_eq!(got.0, 3);
        assert_eq!(
            got.1,
            SwapSpec {
                model: "adult".into(),
                backend: BackendKind::Multiclass,
                path: "m.rsfm".into(),
                shards: 2,
            }
        );
        // Defaults: backend rs, shards 0.
        let (_, sp) = parse_swap_line(
            r#"{"id":1,"swap":{"model":"m","path":"p.rssk"}}"#,
        )
        .unwrap()
        .unwrap();
        assert_eq!(sp.backend, BackendKind::Sketch);
        assert_eq!(sp.shards, 0);
        // Present-but-invalid swap payloads are errors, not fall-through.
        assert!(parse_swap_line(r#"{"id":1,"swap":{"model":"m"}}"#)
            .unwrap()
            .is_err());
        assert!(parse_swap_line(r#"{"swap":{"model":"m","path":"p"}}"#)
            .unwrap()
            .is_err());
        // Non-swap lines are None.
        assert!(parse_swap_line(r#"{"id":1,"model":"m","x":[1]}"#)
            .is_none());
        assert!(parse_swap_line("garbage").is_none());
    }

    #[test]
    fn stats_line_detection() {
        assert_eq!(parse_stats_line(r#"{"id":7,"stats":true}"#), Some(7));
        // Anything else — including near-misses — is not a stats line.
        assert_eq!(parse_stats_line(r#"{"id":7,"stats":false}"#), None);
        assert_eq!(parse_stats_line(r#"{"stats":true}"#), None);
        assert_eq!(
            parse_stats_line(r#"{"id":1,"model":"m","x":[1]}"#),
            None
        );
        assert_eq!(parse_stats_line("garbage"), None);
    }
}
