//! Wire/request types for the coordinator and the TCP JSON-line protocol.
//!
//! One request per line:
//! `{"id": 7, "model": "adult", "backend": "rs", "x": [..d floats..]}`
//! One response per line:
//! `{"id": 7, "y": 0.42, "us": 13.5}` or `{"id": 7, "error": "..."}`.

use super::backend::BackendKind;
use crate::util::json::{self, Json};

/// An inference request routed through the coordinator.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub model: String,
    pub backend: BackendKind,
    pub features: Vec<f32>,
}

/// The coordinator's answer.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub result: Result<f32, String>,
    /// Queue + execution latency in microseconds.
    pub latency_us: f64,
}

impl Request {
    pub fn parse_line(line: &str) -> Result<Request, String> {
        let j = json::parse(line)?;
        let id = j
            .get("id")
            .and_then(|v| v.as_u64())
            .ok_or("missing/invalid id")?;
        let model = j
            .get("model")
            .and_then(|v| v.as_str())
            .ok_or("missing model")?
            .to_string();
        let backend = match j.get("backend").and_then(|v| v.as_str()) {
            Some(s) => BackendKind::parse(s).ok_or("unknown backend")?,
            None => BackendKind::Sketch,
        };
        let features = j.get("x").ok_or("missing x")?.as_f32_flat();
        if features.is_empty() {
            return Err("empty feature vector".into());
        }
        Ok(Request { id, model, backend, features })
    }

    pub fn to_line(&self) -> String {
        let x = Json::Arr(
            self.features.iter().map(|&v| Json::num(v as f64)).collect(),
        );
        json::obj(vec![
            ("id", Json::from_u64(self.id)),
            ("model", Json::Str(self.model.clone())),
            ("backend", Json::Str(self.backend.name().into())),
            ("x", x),
        ])
        .to_string()
    }
}

impl Response {
    pub fn to_line(&self) -> String {
        match &self.result {
            Ok(y) => json::obj(vec![
                ("id", Json::from_u64(self.id)),
                ("y", Json::num(*y as f64)),
                ("us", Json::num(self.latency_us)),
            ])
            .to_string(),
            Err(e) => json::obj(vec![
                ("id", Json::from_u64(self.id)),
                ("error", Json::Str(e.clone())),
            ])
            .to_string(),
        }
    }

    pub fn parse_line(line: &str) -> Result<Response, String> {
        let j = json::parse(line)?;
        let id = j.get("id").and_then(|v| v.as_u64()).ok_or("missing id")?;
        if let Some(err) = j.get("error").and_then(|v| v.as_str()) {
            return Ok(Response {
                id,
                result: Err(err.to_string()),
                latency_us: 0.0,
            });
        }
        let y = j
            .get("y")
            .and_then(|v| v.as_f64())
            .ok_or("missing y")? as f32;
        let us = j.get("us").and_then(|v| v.as_f64()).unwrap_or(0.0);
        Ok(Response { id, result: Ok(y), latency_us: us })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let r = Request {
            id: 42,
            model: "adult".into(),
            backend: BackendKind::NnRust,
            features: vec![1.0, -0.5, 0.0],
        };
        let line = r.to_line();
        let r2 = Request::parse_line(&line).unwrap();
        assert_eq!(r2.id, 42);
        assert_eq!(r2.model, "adult");
        assert_eq!(r2.backend, BackendKind::NnRust);
        assert_eq!(r2.features, r.features);
    }

    #[test]
    fn response_roundtrip() {
        let ok = Response { id: 1, result: Ok(0.5), latency_us: 12.5 };
        let p = Response::parse_line(&ok.to_line()).unwrap();
        assert_eq!(p.id, 1);
        assert_eq!(p.result.unwrap(), 0.5);
        let err = Response {
            id: 2,
            result: Err("boom".into()),
            latency_us: 0.0,
        };
        let p2 = Response::parse_line(&err.to_line()).unwrap();
        assert!(p2.result.is_err());
    }

    #[test]
    fn default_backend_is_sketch() {
        let r =
            Request::parse_line(r#"{"id":1,"model":"m","x":[1]}"#).unwrap();
        assert_eq!(r.backend, BackendKind::Sketch);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Request::parse_line("{}").is_err());
        assert!(Request::parse_line(r#"{"id":1,"model":"m","x":[]}"#)
            .is_err());
        assert!(Request::parse_line("not json").is_err());
    }
}
