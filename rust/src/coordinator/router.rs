//! Request router: owns one dynamic batcher + worker thread per
//! (model, backend) lane, dispatches submissions, tracks latency
//! histograms, and handles shutdown.

use super::backend::{BackendKind, Engine};
use super::batcher::{BatcherConfig, DynamicBatcher, Pending};
use super::protocol::{Request, Response};
use crate::metrics::LatencyHistogram;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::Arc;
use std::time::Instant;

pub use super::batcher::SubmitError;

/// Router-wide configuration.
#[derive(Clone, Debug, Default)]
pub struct RouterConfig {
    pub batcher: BatcherConfig,
}

struct Lane {
    batcher: Arc<DynamicBatcher>,
    worker: Option<std::thread::JoinHandle<()>>,
    latency: Arc<LatencyHistogram>,
}

/// Routes requests to per-(model, backend) lanes.
pub struct Router {
    lanes: HashMap<(String, BackendKind), Lane>,
    pub rejected: AtomicU64,
}

impl Router {
    pub fn new() -> Self {
        Self { lanes: HashMap::new(), rejected: AtomicU64::new(0) }
    }

    /// Register a lane: a backend engine served by one worker thread.
    ///
    /// The engine is constructed *inside* the worker via `factory` — PJRT
    /// executables are not `Send` (the xla crate holds `Rc`s), so they
    /// must live and die on the thread that runs them.  If construction
    /// fails, the lane stays up and answers every request with the error.
    pub fn add_lane<F>(
        &mut self,
        model: &str,
        kind: BackendKind,
        factory: F,
        cfg: &RouterConfig,
    ) where
        F: FnOnce() -> anyhow::Result<Box<dyn Engine>> + Send + 'static,
    {
        let batcher = Arc::new(DynamicBatcher::new(cfg.batcher.clone()));
        let latency = Arc::new(LatencyHistogram::new());
        let worker = {
            let batcher = batcher.clone();
            let latency = latency.clone();
            let label = format!("{model}/{}", kind.name());
            std::thread::Builder::new()
                .name(format!("lane-{label}"))
                .spawn(move || match factory() {
                    Ok(mut engine) => {
                        while let Some(batch) = batcher.next_batch() {
                            Self::run_batch(&mut *engine, batch, &latency);
                        }
                    }
                    Err(e) => {
                        let msg = format!("engine init failed: {e}");
                        while let Some(batch) = batcher.next_batch() {
                            for p in batch {
                                let _ = p.resp_tx.send(Response {
                                    id: p.req.id,
                                    result: Err(msg.clone()),
                                    latency_us: 0.0,
                                });
                            }
                        }
                    }
                })
                .expect("spawn lane worker")
        };
        self.lanes.insert(
            (model.to_string(), kind),
            Lane { batcher, worker: Some(worker), latency },
        );
    }

    fn run_batch(
        engine: &mut dyn Engine,
        batch: Vec<Pending>,
        latency: &LatencyHistogram,
    ) {
        let rows: Vec<Vec<f32>> =
            batch.iter().map(|p| p.req.features.clone()).collect();
        let dim = engine.dim();
        // Validate dims up front so one bad request cannot poison a batch.
        let mut ok_idx = Vec::with_capacity(batch.len());
        let mut ok_rows = Vec::with_capacity(batch.len());
        for (i, (p, row)) in batch.iter().zip(rows).enumerate() {
            if row.len() == dim {
                ok_idx.push(i);
                ok_rows.push(row);
            } else {
                let _ = p.resp_tx.send(Response {
                    id: p.req.id,
                    result: Err(format!(
                        "dim mismatch: got {}, want {dim}",
                        row.len()
                    )),
                    latency_us: 0.0,
                });
            }
        }
        let outs = engine.eval_batch(&ok_rows);
        match outs {
            Ok(values) => {
                for (slot, value) in ok_idx.iter().zip(values) {
                    let p = &batch[*slot];
                    let dur = p.enqueued.elapsed();
                    latency.record(dur);
                    let _ = p.resp_tx.send(Response {
                        id: p.req.id,
                        result: Ok(value),
                        latency_us: dur.as_nanos() as f64 / 1e3,
                    });
                }
            }
            Err(e) => {
                for slot in &ok_idx {
                    let p = &batch[*slot];
                    let _ = p.resp_tx.send(Response {
                        id: p.req.id,
                        result: Err(format!("engine error: {e}")),
                        latency_us: 0.0,
                    });
                }
            }
        }
    }

    /// Submit a request; the response arrives on the returned channel.
    pub fn submit(&self, req: Request) -> Result<Receiver<Response>, SubmitError> {
        let key = (req.model.clone(), req.backend);
        let lane = match self.lanes.get(&key) {
            Some(l) => l,
            None => {
                self.rejected.fetch_add(1, Ordering::Relaxed);
                // Unknown lane: answer immediately with an error response.
                let (tx, rx) = channel();
                let _ = tx.send(Response {
                    id: req.id,
                    result: Err(format!(
                        "no lane for model={} backend={}",
                        req.model,
                        req.backend.name()
                    )),
                    latency_us: 0.0,
                });
                return Ok(rx);
            }
        };
        let (tx, rx) = channel();
        lane.batcher
            .submit(Pending { req, enqueued: Instant::now(), resp_tx: tx })
            .map(|()| rx)
            .map_err(|e| {
                self.rejected.fetch_add(1, Ordering::Relaxed);
                e
            })
    }

    /// Blocking convenience: submit and wait.
    pub fn call(&self, req: Request) -> Response {
        let id = req.id;
        match self.submit(req) {
            Ok(rx) => rx.recv().unwrap_or(Response {
                id,
                result: Err("worker dropped".into()),
                latency_us: 0.0,
            }),
            Err(e) => Response {
                id,
                result: Err(format!("rejected: {e:?}")),
                latency_us: 0.0,
            },
        }
    }

    pub fn lane_stats(&self) -> Vec<(String, String, u64, u64, String)> {
        self.lanes
            .iter()
            .map(|((m, k), lane)| {
                (
                    m.clone(),
                    k.name().to_string(),
                    lane.batcher.submitted.load(Ordering::Relaxed),
                    lane.batcher.batches.load(Ordering::Relaxed),
                    lane.latency.summary(),
                )
            })
            .collect()
    }

    pub fn latency_of(&self, model: &str, kind: BackendKind)
        -> Option<Arc<LatencyHistogram>> {
        self.lanes
            .get(&(model.to_string(), kind))
            .map(|l| l.latency.clone())
    }

    /// Graceful shutdown: close all lanes, join workers (drains queues).
    pub fn shutdown(&mut self) {
        for lane in self.lanes.values() {
            lane.batcher.close();
        }
        for lane in self.lanes.values_mut() {
            if let Some(h) = lane.worker.take() {
                let _ = h.join();
            }
        }
    }
}

impl Default for Router {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Test engine: y = sum(x) (+ optional failure injection).
    struct SumEngine {
        dim: usize,
        fail: bool,
    }

    impl Engine for SumEngine {
        fn dim(&self) -> usize {
            self.dim
        }

        fn eval_batch(&mut self, rows: &[Vec<f32>])
            -> anyhow::Result<Vec<f32>> {
            if self.fail {
                anyhow::bail!("injected failure");
            }
            Ok(rows.iter().map(|r| r.iter().sum()).collect())
        }
    }

    fn mk_router(fail: bool) -> Router {
        let mut r = Router::new();
        r.add_lane(
            "m",
            BackendKind::Sketch,
            move || Ok(Box::new(SumEngine { dim: 3, fail }) as Box<dyn Engine>),
            &RouterConfig::default(),
        );
        r
    }

    fn req(id: u64, x: Vec<f32>) -> Request {
        Request {
            id,
            model: "m".into(),
            backend: BackendKind::Sketch,
            features: x,
        }
    }

    #[test]
    fn routes_and_answers() {
        let r = mk_router(false);
        let resp = r.call(req(1, vec![1.0, 2.0, 3.0]));
        assert_eq!(resp.id, 1);
        assert_eq!(resp.result.unwrap(), 6.0);
        assert!(resp.latency_us > 0.0);
    }

    #[test]
    fn unknown_lane_gets_error_response() {
        let r = mk_router(false);
        let resp = r.call(Request {
            id: 9,
            model: "nope".into(),
            backend: BackendKind::Sketch,
            features: vec![1.0],
        });
        assert!(resp.result.is_err());
        assert_eq!(r.rejected.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn dim_mismatch_isolated_within_batch() {
        let r = mk_router(false);
        let bad = r.call(req(1, vec![1.0]));
        assert!(bad.result.is_err());
        let good = r.call(req(2, vec![1.0, 1.0, 1.0]));
        assert_eq!(good.result.unwrap(), 3.0);
    }

    #[test]
    fn engine_failure_reported_not_lost() {
        let r = mk_router(true);
        let resp = r.call(req(1, vec![1.0, 2.0, 3.0]));
        assert!(resp.result.unwrap_err().contains("injected"));
    }

    #[test]
    fn every_request_gets_exactly_one_response() {
        // The central no-loss/no-dup invariant under concurrency.
        let r = std::sync::Arc::new(mk_router(false));
        let n_threads = 8;
        let per_thread = 200u64;
        let mut handles = Vec::new();
        for t in 0..n_threads {
            let r = r.clone();
            handles.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                for i in 0..per_thread {
                    let id = t * per_thread + i;
                    let resp =
                        r.call(req(id, vec![id as f32, 0.0, 1.0]));
                    assert_eq!(resp.id, id);
                    got.push((id, resp.result.unwrap()));
                }
                got
            }));
        }
        let mut all = std::collections::HashMap::new();
        for h in handles {
            for (id, v) in h.join().unwrap() {
                assert!(all.insert(id, v).is_none(), "dup id {id}");
                assert_eq!(v, id as f32 + 1.0);
            }
        }
        assert_eq!(all.len(), (n_threads * per_thread) as usize);
    }

    #[test]
    fn stats_track_submissions() {
        let r = mk_router(false);
        for i in 0..10 {
            let _ = r.call(req(i, vec![0.0, 0.0, 0.0]));
        }
        let stats = r.lane_stats();
        assert_eq!(stats.len(), 1);
        assert_eq!(stats[0].2, 10); // submitted
        assert!(stats[0].3 >= 1); // batches
    }
}
