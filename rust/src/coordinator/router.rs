//! Request router: owns one dynamic batcher + worker thread per
//! (model, backend) lane, dispatches submissions, tracks per-lane SLO
//! counters (latency quantiles + error budget), and handles shutdown.
//!
//! The `stats` wire verb (`{"id": N, "stats": true}`) is answered
//! here, inline on the reactor thread — see [`Router::stats_line`] for
//! the response schema.

use super::backend::{BackendKind, Engine};
use super::batcher::{
    BatcherConfig, DynamicBatcher, Pending, Responder, ResponseSink,
};
use super::protocol::{Request, Response};
use crate::metrics::slo::{LaneSlo, RemoteShardStats};
use crate::util::json::{self, Json};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::Arc;
use std::time::Instant;

pub use super::batcher::SubmitError;

/// Router-wide configuration.
#[derive(Clone, Debug, Default)]
pub struct RouterConfig {
    pub batcher: BatcherConfig,
}

struct Lane {
    batcher: Arc<DynamicBatcher>,
    worker: Option<std::thread::JoinHandle<()>>,
    slo: Arc<LaneSlo>,
}

/// Routes requests to per-(model, backend) lanes.
pub struct Router {
    lanes: HashMap<(String, BackendKind), Lane>,
    pub rejected: AtomicU64,
    /// Remote shard sets whose counters the `stats` verb reports,
    /// keyed by model name (registered at serve start, read-only
    /// after).
    shard_stats: Vec<(String, Arc<RemoteShardStats>)>,
}

impl Router {
    pub fn new() -> Self {
        Self {
            lanes: HashMap::new(),
            rejected: AtomicU64::new(0),
            shard_stats: Vec::new(),
        }
    }

    /// Register a lane: a backend engine served by one worker thread.
    ///
    /// The engine is constructed *inside* the worker via `factory` — PJRT
    /// executables are not `Send` (the xla crate holds `Rc`s), so they
    /// must live and die on the thread that runs them.  If construction
    /// fails, the lane stays up and answers every request with the error.
    pub fn add_lane<F>(
        &mut self,
        model: &str,
        kind: BackendKind,
        factory: F,
        cfg: &RouterConfig,
    ) where
        F: FnOnce() -> anyhow::Result<Box<dyn Engine>> + Send + 'static,
    {
        let batcher = Arc::new(DynamicBatcher::new(cfg.batcher.clone()));
        let slo = Arc::new(LaneSlo::new());
        let worker = {
            let batcher = batcher.clone();
            let slo = slo.clone();
            let label = format!("{model}/{}", kind.name());
            std::thread::Builder::new()
                .name(format!("lane-{label}"))
                .spawn(move || {
                    // Unwind guard: if the engine panics, close the
                    // batcher (new submissions fail fast with Closed
                    // instead of queueing into a dead lane forever) and
                    // drop whatever is still queued so every responder
                    // fires — a long-running server must never strand a
                    // client on a request nothing will drain.
                    struct DrainGuard(Arc<DynamicBatcher>);
                    impl Drop for DrainGuard {
                        fn drop(&mut self) {
                            self.0.close();
                            while self.0.next_batch().is_some() {}
                        }
                    }
                    let _guard = DrainGuard(batcher.clone());
                    match factory() {
                        Ok(mut engine) => {
                            while let Some(batch) = batcher.next_batch() {
                                Self::run_batch(
                                    &mut *engine,
                                    batch,
                                    &slo,
                                );
                            }
                        }
                        Err(e) => {
                            let msg = format!("engine init failed: {e}");
                            while let Some(batch) = batcher.next_batch() {
                                for p in batch {
                                    let id = p.req.id;
                                    slo.record_error();
                                    p.responder.send(
                                        Response::err(Some(id),
                                                      msg.clone()),
                                    );
                                }
                            }
                        }
                    }
                })
                .expect("spawn lane worker")
        };
        let replaced = self.lanes.insert(
            (model.to_string(), kind),
            Lane { batcher, worker: Some(worker), slo },
        );
        // Re-registering a (model, backend) key replaces the lane
        // (last registration wins); shut the old one down properly —
        // close its batcher so its worker drains and exits — instead
        // of leaking a parked worker thread for the process lifetime.
        if let Some(mut old) = replaced {
            old.batcher.close();
            if let Some(h) = old.worker.take() {
                let _ = h.join();
            }
        }
    }

    fn run_batch(
        engine: &mut dyn Engine,
        batch: Vec<Pending>,
        slo: &LaneSlo,
    ) {
        let dim = engine.dim();
        // Feature vectors are MOVED out of the requests — the hot path
        // does zero per-request allocations (the seed cloned every row
        // before validating it).  Dims are checked up front so one bad
        // request cannot poison a batch.
        let mut ok = Vec::with_capacity(batch.len());
        let mut rows = Vec::with_capacity(batch.len());
        for mut p in batch {
            let row = std::mem::take(&mut p.req.features);
            if row.len() == dim {
                rows.push(row);
                ok.push(p);
            } else {
                let id = p.req.id;
                slo.record_error();
                p.responder.send(Response::err(
                    Some(id),
                    format!("dim mismatch: got {}, want {dim}", row.len()),
                ));
            }
        }
        // Score vectors are materialized once per batch iff anyone in
        // it asked (still ONE engine call); each response then carries
        // its own row's vector only if ITS request asked.
        let want_scores = ok.iter().any(|p| p.req.want_scores);
        match engine.eval_batch_ex(&rows, want_scores) {
            Ok(out) => {
                // If the engine returns fewer values than rows (engine
                // bug), the unmatched responders answer "worker
                // dropped" on drop — never silence.
                let scores = out.scores;
                for (i, (p, value)) in
                    ok.into_iter().zip(out.values).enumerate()
                {
                    let dur = p.enqueued.elapsed();
                    slo.record_ok(dur);
                    let id = p.req.id;
                    // Slice this row out of the flat matrix — the only
                    // per-request score allocation is for requests that
                    // actually asked.
                    let row_scores = if p.req.want_scores {
                        scores
                            .as_ref()
                            .and_then(|m| m.row(i))
                            .map(|s| s.to_vec())
                    } else {
                        None
                    };
                    p.responder.send(Response {
                        id: Some(id),
                        result: Ok(value),
                        scores: row_scores,
                        latency_us: dur.as_nanos() as f64 / 1e3,
                    });
                }
            }
            Err(e) => {
                let msg = format!("engine error: {e}");
                for p in ok {
                    let id = p.req.id;
                    slo.record_error();
                    p.responder.send(Response::err(Some(id), msg.clone()));
                }
            }
        }
    }

    /// Submit a request with an explicit response sink.
    ///
    /// Exactly one response is guaranteed to reach the sink: unknown
    /// lanes and backpressure are answered immediately (the error cases
    /// additionally return `Err` so callers can track rejections), and
    /// accepted requests carry a [`Responder`] whose drop guard answers
    /// `"worker dropped"` if the lane dies mid-flight.
    pub fn submit_sink(
        &self,
        req: Request,
        sink: ResponseSink,
    ) -> Result<(), SubmitError> {
        let id = req.id;
        let responder = Responder::new(id, sink);
        let lane = match self.lanes.get(&(req.model.clone(), req.backend)) {
            Some(l) => l,
            None => {
                self.rejected.fetch_add(1, Ordering::Relaxed);
                responder.send(Response::err(
                    Some(id),
                    format!(
                        "no lane for model={} backend={}",
                        req.model,
                        req.backend.name()
                    ),
                ));
                return Ok(());
            }
        };
        match lane.batcher.submit(Pending {
            req,
            enqueued: Instant::now(),
            responder,
        }) {
            Ok(()) => Ok(()),
            Err((p, e)) => {
                self.rejected.fetch_add(1, Ordering::Relaxed);
                p.responder.send(Response::err(
                    Some(id),
                    format!("backpressure: {e:?}"),
                ));
                Err(e)
            }
        }
    }

    /// Submit a request; the response arrives on the returned channel.
    /// On `Err` the (dropped) channel still received the backpressure
    /// response — in-process callers use the `Err` directly.
    pub fn submit(&self, req: Request) -> Result<Receiver<Response>, SubmitError> {
        let (tx, rx) = channel();
        self.submit_sink(req, ResponseSink::Channel(tx))?;
        Ok(rx)
    }

    /// Blocking convenience: submit and wait.
    pub fn call(&self, req: Request) -> Response {
        let id = req.id;
        match self.submit(req) {
            Ok(rx) => rx.recv().unwrap_or_else(|_| {
                Response::err(Some(id), "worker dropped")
            }),
            Err(e) => Response::err(Some(id), format!("rejected: {e:?}")),
        }
    }

    pub fn lane_stats(&self) -> Vec<(String, String, u64, u64, String)> {
        self.lanes
            .iter()
            .map(|((m, k), lane)| {
                (
                    m.clone(),
                    k.name().to_string(),
                    lane.batcher.submitted.load(Ordering::Relaxed),
                    lane.batcher.batches.load(Ordering::Relaxed),
                    lane.slo.latency.summary(),
                )
            })
            .collect()
    }

    pub fn slo_of(&self, model: &str, kind: BackendKind)
        -> Option<Arc<LaneSlo>> {
        self.lanes
            .get(&(model.to_string(), kind))
            .map(|l| l.slo.clone())
    }

    /// Attach a remote shard set's counters to the `stats` verb under
    /// `model`.  Called during serve start, before the reactor runs.
    pub fn register_shard_stats(
        &mut self,
        model: &str,
        stats: Arc<RemoteShardStats>,
    ) {
        self.shard_stats.push((model.to_string(), stats));
    }

    /// The `stats` verb response: one JSON line with every lane's SLO
    /// counters and every registered remote shard set's replication
    /// counters.
    ///
    /// Schema:
    /// `{"id": N, "stats": {"rejected": R, "lanes": [{"model", "backend",
    /// "submitted", "batches", "ok", "errors", "latency": {"n",
    /// "mean_us", "p50_us", "p99_us", "p999_us"}}, ...], "shards":
    /// [{"model", "shards": [per-shard objects with gathers/errors/
    /// hedges/failovers/reconnects/quarantines/discarded/latency and
    /// nested per-replica counters]}, ...]}}`.
    ///
    /// The error budget over a window at target availability `t` is
    /// `(ok + errors) × (1 − t) − errors`, diffing two snapshots —
    /// see `metrics::slo`.
    pub fn stats_line(&self, id: u64) -> String {
        let mut lanes: Vec<(&String, &BackendKind, &Lane)> = self
            .lanes
            .iter()
            .map(|((m, k), lane)| (m, k, lane))
            .collect();
        lanes.sort_by(|a, b| (a.0, a.1.name()).cmp(&(b.0, b.1.name())));
        let lanes = Json::Arr(
            lanes
                .into_iter()
                .map(|(m, k, lane)| {
                    json::obj(vec![
                        ("model", Json::Str(m.clone())),
                        ("backend", Json::Str(k.name().to_string())),
                        (
                            "submitted",
                            Json::from_u64(
                                lane.batcher
                                    .submitted
                                    .load(Ordering::Relaxed),
                            ),
                        ),
                        (
                            "batches",
                            Json::from_u64(
                                lane.batcher
                                    .batches
                                    .load(Ordering::Relaxed),
                            ),
                        ),
                        (
                            "ok",
                            Json::from_u64(lane.slo.ok_count()),
                        ),
                        (
                            "errors",
                            Json::from_u64(lane.slo.error_count()),
                        ),
                        (
                            "latency",
                            crate::metrics::slo::histogram_json(
                                &lane.slo.latency,
                            ),
                        ),
                    ])
                })
                .collect(),
        );
        let shards = Json::Arr(
            self.shard_stats
                .iter()
                .map(|(m, stats)| {
                    json::obj(vec![
                        ("model", Json::Str(m.clone())),
                        ("shards", stats.to_json()),
                    ])
                })
                .collect(),
        );
        json::obj(vec![
            ("id", Json::from_u64(id)),
            (
                "stats",
                json::obj(vec![
                    (
                        "rejected",
                        Json::from_u64(
                            self.rejected.load(Ordering::Relaxed),
                        ),
                    ),
                    ("lanes", lanes),
                    ("shards", shards),
                ]),
            ),
        ])
        .to_string()
    }

    /// Graceful shutdown: close all lanes, join workers (drains queues).
    pub fn shutdown(&mut self) {
        for lane in self.lanes.values() {
            lane.batcher.close();
        }
        for lane in self.lanes.values_mut() {
            if let Some(h) = lane.worker.take() {
                let _ = h.join();
            }
        }
    }
}

impl Default for Router {
    fn default() -> Self {
        Self::new()
    }
}

/// The inference plane behind the reactor: parse a request line, submit
/// it with the reactor completion sink.  Exactly one response per line:
/// parse failures answer immediately with a best-effort-recovered id,
/// accepted requests carry a [`Responder`] whose drop guard fires if
/// the lane dies, and unknown-lane/backpressure errors are answered by
/// `submit_sink` itself.
#[cfg(target_os = "linux")]
impl super::net::LineHandler for Router {
    fn handle_line(
        &self,
        line: String,
        sender: super::net::CompletionSender,
    ) {
        use super::protocol::extract_id;
        // The stats verb is answered inline (counter loads + JSON
        // rendering only — no lane round-trip, no kernel work).
        if let Some(rid) = super::protocol::parse_stats_line(&line) {
            sender.send_line(self.stats_line(rid));
            return;
        }
        match Request::parse_line(&line) {
            Ok(req) => {
                let _ = self
                    .submit_sink(req, ResponseSink::Reactor(sender));
            }
            Err(e) => sender.send(Response::err(
                extract_id(&line),
                format!("bad request: {e}"),
            )),
        }
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Test engine: y = sum(x) (+ optional failure injection).
    struct SumEngine {
        dim: usize,
        fail: bool,
    }

    impl Engine for SumEngine {
        fn dim(&self) -> usize {
            self.dim
        }

        fn eval_batch(&mut self, rows: &[Vec<f32>])
            -> anyhow::Result<Vec<f32>> {
            if self.fail {
                anyhow::bail!("injected failure");
            }
            Ok(rows.iter().map(|r| r.iter().sum()).collect())
        }
    }

    fn mk_router(fail: bool) -> Router {
        let mut r = Router::new();
        r.add_lane(
            "m",
            BackendKind::Sketch,
            move || Ok(Box::new(SumEngine { dim: 3, fail }) as Box<dyn Engine>),
            &RouterConfig::default(),
        );
        r
    }

    fn req(id: u64, x: Vec<f32>) -> Request {
        Request {
            id,
            model: "m".into(),
            backend: BackendKind::Sketch,
            features: x,
            want_scores: false,
        }
    }

    #[test]
    fn routes_and_answers() {
        let r = mk_router(false);
        let resp = r.call(req(1, vec![1.0, 2.0, 3.0]));
        assert_eq!(resp.id, Some(1));
        assert_eq!(resp.result.unwrap(), 6.0);
        assert!(resp.latency_us > 0.0);
    }

    #[test]
    fn unknown_lane_gets_error_response() {
        let r = mk_router(false);
        let resp = r.call(Request {
            id: 9,
            model: "nope".into(),
            backend: BackendKind::Sketch,
            features: vec![1.0],
            want_scores: false,
        });
        assert!(resp.result.is_err());
        assert_eq!(r.rejected.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn dim_mismatch_isolated_within_batch() {
        let r = mk_router(false);
        let bad = r.call(req(1, vec![1.0]));
        assert!(bad.result.is_err());
        let good = r.call(req(2, vec![1.0, 1.0, 1.0]));
        assert_eq!(good.result.unwrap(), 3.0);
    }

    #[test]
    fn engine_failure_reported_not_lost() {
        let r = mk_router(true);
        let resp = r.call(req(1, vec![1.0, 2.0, 3.0]));
        assert!(resp.result.unwrap_err().contains("injected"));
    }

    #[test]
    fn every_request_gets_exactly_one_response() {
        // The central no-loss/no-dup invariant under concurrency.
        let r = std::sync::Arc::new(mk_router(false));
        let n_threads = 8;
        let per_thread = 200u64;
        let mut handles = Vec::new();
        for t in 0..n_threads {
            let r = r.clone();
            handles.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                for i in 0..per_thread {
                    let id = t * per_thread + i;
                    let resp =
                        r.call(req(id, vec![id as f32, 0.0, 1.0]));
                    assert_eq!(resp.id, Some(id));
                    got.push((id, resp.result.unwrap()));
                }
                got
            }));
        }
        let mut all = std::collections::HashMap::new();
        for h in handles {
            for (id, v) in h.join().unwrap() {
                assert!(all.insert(id, v).is_none(), "dup id {id}");
                assert_eq!(v, id as f32 + 1.0);
            }
        }
        assert_eq!(all.len(), (n_threads * per_thread) as usize);
    }

    /// Engine that dies (panics) on every eval — models a lane tearing
    /// down with requests in flight.
    struct DyingEngine;

    impl Engine for DyingEngine {
        fn dim(&self) -> usize {
            3
        }

        fn eval_batch(&mut self, _rows: &[Vec<f32>])
            -> anyhow::Result<Vec<f32>> {
            panic!("lane died mid-flight");
        }
    }

    #[test]
    fn lane_teardown_mid_flight_answers_every_request() {
        // The exactly-one-response invariant through engine/lane
        // teardown: the drained batch's responders fire during the
        // worker's unwind, queued-but-undrained requests fire when the
        // router (and with it the batcher queue) is dropped.  The seed
        // lost all of these silently.
        let mut r = Router::new();
        r.add_lane(
            "m",
            BackendKind::Sketch,
            move || Ok(Box::new(DyingEngine) as Box<dyn Engine>),
            &RouterConfig::default(),
        );
        let mut rxs = Vec::new();
        for i in 0..40u64 {
            if let Ok(rx) = r.submit(req(i, vec![0.0, 0.0, 1.0])) {
                rxs.push((i, rx));
            }
        }
        assert!(!rxs.is_empty());
        drop(r); // shutdown: close + join dead worker, drop the queue
        for (i, rx) in rxs {
            let resp = rx
                .recv_timeout(std::time::Duration::from_secs(5))
                .expect("a response must arrive, not channel-drop");
            assert_eq!(resp.id, Some(i));
            assert!(
                resp.result.unwrap_err().contains("worker dropped"),
                "request {i} must get the worker-dropped error"
            );
        }
    }

    #[test]
    fn truncated_engine_output_still_answers_all() {
        // An engine that returns fewer values than rows: matched rows
        // get answers, the rest get worker-dropped — never silence.
        struct ShortEngine;
        impl Engine for ShortEngine {
            fn dim(&self) -> usize {
                3
            }
            fn eval_batch(&mut self, rows: &[Vec<f32>])
                -> anyhow::Result<Vec<f32>> {
                Ok(rows[..rows.len() / 2]
                    .iter()
                    .map(|r| r.iter().sum())
                    .collect())
            }
        }
        let mut r = Router::new();
        let cfg = RouterConfig {
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: std::time::Duration::from_secs(30),
                queue_cap: 64,
            },
        };
        r.add_lane(
            "m",
            BackendKind::Sketch,
            move || Ok(Box::new(ShortEngine) as Box<dyn Engine>),
            &cfg,
        );
        let rxs: Vec<_> = (0..8u64)
            .map(|i| (i, r.submit(req(i, vec![1.0, 2.0, 3.0])).unwrap()))
            .collect();
        let mut answered = 0;
        let mut dropped = 0;
        for (i, rx) in rxs {
            let resp = rx
                .recv_timeout(std::time::Duration::from_secs(5))
                .unwrap();
            assert_eq!(resp.id, Some(i));
            match resp.result {
                Ok(v) => {
                    assert_eq!(v, 6.0);
                    answered += 1;
                }
                Err(e) => {
                    assert!(e.contains("worker dropped"));
                    dropped += 1;
                }
            }
        }
        assert_eq!(answered, 4);
        assert_eq!(dropped, 4);
    }

    #[test]
    fn stats_track_submissions() {
        let r = mk_router(false);
        for i in 0..10 {
            let _ = r.call(req(i, vec![0.0, 0.0, 0.0]));
        }
        let stats = r.lane_stats();
        assert_eq!(stats.len(), 1);
        assert_eq!(stats[0].2, 10); // submitted
        assert!(stats[0].3 >= 1); // batches
    }

    #[test]
    fn stats_line_reports_slo_counters_as_json() {
        let mut r = mk_router(false);
        for i in 0..5 {
            let _ = r.call(req(i, vec![0.0, 0.0, 0.0]));
        }
        // One dim-mismatch error charged to the lane's budget.
        let bad = r.call(req(99, vec![1.0]));
        assert!(bad.result.is_err());
        r.register_shard_stats(
            "m",
            Arc::new(RemoteShardStats::new(&[vec![
                "a0".to_string(),
                "a1".to_string(),
            ]])),
        );
        let line = r.stats_line(31);
        let j = json::parse(&line).unwrap();
        assert_eq!(j.get("id").unwrap().as_u64(), Some(31));
        let stats = j.get("stats").unwrap();
        assert_eq!(stats.get("rejected").unwrap().as_u64(), Some(0));
        let lanes = stats.get("lanes").unwrap().as_arr().unwrap();
        assert_eq!(lanes.len(), 1);
        assert_eq!(lanes[0].get("model").unwrap().as_str(), Some("m"));
        assert_eq!(lanes[0].get("ok").unwrap().as_u64(), Some(5));
        assert_eq!(lanes[0].get("errors").unwrap().as_u64(), Some(1));
        let lat = lanes[0].get("latency").unwrap();
        assert_eq!(lat.get("n").unwrap().as_u64(), Some(5));
        assert!(lat.get("p999_us").unwrap().as_f64().unwrap() > 0.0);
        let shards = stats.get("shards").unwrap().as_arr().unwrap();
        assert_eq!(shards.len(), 1);
        assert_eq!(
            shards[0].get("model").unwrap().as_str(),
            Some("m")
        );
        let per_shard =
            shards[0].get("shards").unwrap().as_arr().unwrap();
        assert_eq!(per_shard.len(), 1);
        assert_eq!(
            per_shard[0]
                .get("replicas")
                .unwrap()
                .as_arr()
                .unwrap()
                .len(),
            2
        );
    }

    #[test]
    fn lane_slo_exposed_for_lookup() {
        let r = mk_router(false);
        let _ = r.call(req(1, vec![0.0, 0.0, 0.0]));
        let slo = r.slo_of("m", BackendKind::Sketch).unwrap();
        assert_eq!(slo.ok_count(), 1);
        assert!(r.slo_of("nope", BackendKind::Sketch).is_none());
    }
}
