//! Request router: owns one dynamic batcher + worker thread per
//! (model, backend) lane, dispatches submissions, tracks per-lane SLO
//! counters (latency quantiles + error budget), and handles shutdown.
//!
//! Lanes are VERSIONED and hot-swappable: every lane carries a
//! monotonically increasing version assigned at registration, every
//! response it produces is stamped with that version (`"v"` on the
//! wire), and [`Router::add_lane`] atomically replaces a live lane —
//! the old worker drains its queue to completion (in-flight requests
//! finish on the old engine, stamped with the old version) while new
//! submissions land on the new lane.  The `swap` wire verb rides this:
//! it loads and validates a new model on a dedicated admin thread
//! (never the reactor), and only a fully validated load flips the
//! lane.  The submit path closes the one race this opens: a request
//! that grabbed the old lane right before the flip retries onto the
//! replacement when the old batcher reports `Closed`.
//!
//! A lane's queue is FIFO across verbs: queries and `update` mutations
//! drain in submission order (split into maximal same-verb runs so
//! each still batches), which is what makes the read-your-writes
//! guarantee hold per connection — an update acked before a query was
//! sent is visible to that query.
//!
//! The `stats` wire verb (`{"id": N, "stats": true}`) is answered
//! here, inline on the reactor thread — see [`Router::stats_line`] for
//! the response schema.

use super::backend::{BackendKind, Engine, UpdateRow};
use super::batcher::{
    BatcherConfig, DynamicBatcher, Pending, Responder, ResponseSink,
};
use super::protocol::{Request, Response};
#[cfg(target_os = "linux")]
use super::protocol::SwapSpec;
use crate::metrics::slo::{LaneSlo, RemoteShardStats, UpdateSlo};
use crate::util::json::{self, Json};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Mutex, OnceLock, RwLock, Weak};
use std::time::Instant;

pub use super::batcher::SubmitError;

/// Router-wide configuration.
#[derive(Clone, Debug, Default)]
pub struct RouterConfig {
    pub batcher: BatcherConfig,
}

struct Lane {
    batcher: Arc<DynamicBatcher>,
    worker: Mutex<Option<std::thread::JoinHandle<()>>>,
    slo: Arc<LaneSlo>,
    /// Monotonic registration version — the version-attribution handle
    /// stamped into every response this lane produces.
    version: u64,
    /// The engine's live-update counters, published by the worker once
    /// the engine is constructed (stays empty for immutable backends).
    update: Arc<OnceLock<Arc<UpdateSlo>>>,
}

/// What `enable_swap` arms: a weak self-reference (the admin thread
/// must not keep a torn-down router alive) plus the lane config swapped
/// lanes are built with.
#[allow(dead_code)] // `cfg` is read by the Linux-only swap thread
struct SwapCtx {
    me: Weak<Router>,
    cfg: RouterConfig,
}

/// Routes requests to per-(model, backend) lanes.
pub struct Router {
    lanes: RwLock<HashMap<(String, BackendKind), Arc<Lane>>>,
    pub rejected: AtomicU64,
    /// Remote shard sets whose counters the `stats` verb reports,
    /// keyed by model name (registered at serve start).
    shard_stats: Mutex<Vec<(String, Arc<RemoteShardStats>)>>,
    /// Source of lane versions; `add_lane` (and through it, `swap`)
    /// increments.
    next_version: AtomicU64,
    swap: OnceLock<SwapCtx>,
}

impl Router {
    pub fn new() -> Self {
        Self {
            lanes: RwLock::new(HashMap::new()),
            rejected: AtomicU64::new(0),
            shard_stats: Mutex::new(Vec::new()),
            next_version: AtomicU64::new(0),
            swap: OnceLock::new(),
        }
    }

    /// Register a lane: a backend engine served by one worker thread.
    /// Returns the lane's version.
    ///
    /// The engine is constructed *inside* the worker via `factory` — PJRT
    /// executables are not `Send` (the xla crate holds `Rc`s), so they
    /// must live and die on the thread that runs them.  If construction
    /// fails, the lane stays up and answers every request with the error.
    ///
    /// Re-registering a live (model, backend) key is the HOT-SWAP
    /// primitive: the new lane is inserted under the map lock (new
    /// submissions route to it from that instant), then the old lane is
    /// drained — its batcher closes, its worker finishes every request
    /// already queued on the old engine, and the thread is joined.  No
    /// request is lost, and every response is attributable to exactly
    /// one version.
    pub fn add_lane<F>(
        &self,
        model: &str,
        kind: BackendKind,
        factory: F,
        cfg: &RouterConfig,
    ) -> u64
    where
        F: FnOnce() -> anyhow::Result<Box<dyn Engine>> + Send + 'static,
    {
        // ORDERING: Relaxed — unique-id allocator; only atomicity of
        // the increment matters, not ordering against other memory.
        let version = self.next_version.fetch_add(1, Ordering::Relaxed) + 1;
        let batcher = Arc::new(DynamicBatcher::new(cfg.batcher.clone()));
        let slo = Arc::new(LaneSlo::new());
        let update: Arc<OnceLock<Arc<UpdateSlo>>> =
            Arc::new(OnceLock::new());
        let worker = {
            let batcher = batcher.clone();
            let slo = slo.clone();
            let update = update.clone();
            let label = format!("{model}/{}", kind.name());
            std::thread::Builder::new()
                .name(format!("lane-{label}"))
                .spawn(move || {
                    // Unwind guard: if the engine panics, close the
                    // batcher (new submissions fail fast with Closed
                    // instead of queueing into a dead lane forever) and
                    // drop whatever is still queued so every responder
                    // fires — a long-running server must never strand a
                    // client on a request nothing will drain.
                    struct DrainGuard(Arc<DynamicBatcher>);
                    impl Drop for DrainGuard {
                        fn drop(&mut self) {
                            self.0.close();
                            while self.0.next_batch().is_some() {}
                        }
                    }
                    let _guard = DrainGuard(batcher.clone());
                    match factory() {
                        Ok(mut engine) => {
                            if let Some(u) = engine.plane_stats() {
                                let _ = update.set(u);
                            }
                            while let Some(batch) = batcher.next_batch() {
                                Self::run_batch(
                                    &mut *engine,
                                    batch,
                                    &slo,
                                    version,
                                );
                            }
                        }
                        Err(e) => {
                            let msg = format!("engine init failed: {e}");
                            while let Some(batch) = batcher.next_batch() {
                                for p in batch {
                                    let id = p.req.id;
                                    slo.record_error();
                                    p.responder.send(Response {
                                        version: Some(version),
                                        ..Response::err(Some(id),
                                                        msg.clone())
                                    });
                                }
                            }
                        }
                    }
                })
                .expect("spawn lane worker")
        };
        let lane = Arc::new(Lane {
            batcher,
            worker: Mutex::new(Some(worker)),
            slo,
            version,
            update,
        });
        let replaced = self
            .lanes
            .write()
            .unwrap()
            .insert((model.to_string(), kind), lane);
        if let Some(old) = replaced {
            Self::drain_lane(&old);
        }
        version
    }

    /// Drain one lane to completion: close its batcher (queued and
    /// in-flight requests still flow through the worker — nothing is
    /// dropped) and join the worker thread.  Shared by lane
    /// replacement (hot-swap), shutdown, and the signal-driven drain.
    fn drain_lane(lane: &Arc<Lane>) {
        lane.batcher.close();
        if let Some(h) = lane.worker.lock().unwrap().take() {
            let _ = h.join();
        }
    }

    /// Drain one queue pull.  The pull may interleave queries and
    /// `update` mutations; they are split into maximal same-verb runs
    /// in FIFO order — queries batch with queries, updates batch with
    /// updates, and the submission order across verbs is preserved (an
    /// update never reorders past a later query, which is what makes
    /// update acks mean "visible to every query after me").
    fn run_batch(
        engine: &mut dyn Engine,
        batch: Vec<Pending>,
        slo: &LaneSlo,
        version: u64,
    ) {
        let mut it = batch.into_iter().peekable();
        while let Some(head) = it.peek() {
            let is_update = head.req.update.is_some();
            let mut run = Vec::new();
            while let Some(p) = it.peek() {
                if p.req.update.is_some() != is_update {
                    break;
                }
                run.push(it.next().unwrap());
            }
            if is_update {
                Self::run_updates(engine, run, slo, version);
            } else {
                Self::run_queries(engine, run, slo, version);
            }
        }
    }

    fn run_queries(
        engine: &mut dyn Engine,
        batch: Vec<Pending>,
        slo: &LaneSlo,
        version: u64,
    ) {
        let dim = engine.dim();
        // Feature vectors are MOVED out of the requests — the hot path
        // does zero per-request allocations (the seed cloned every row
        // before validating it).  Dims are checked up front so one bad
        // request cannot poison a batch.
        let mut ok = Vec::with_capacity(batch.len());
        let mut rows = Vec::with_capacity(batch.len());
        for mut p in batch {
            let row = std::mem::take(&mut p.req.features);
            if row.len() == dim {
                rows.push(row);
                ok.push(p);
            } else {
                let id = p.req.id;
                slo.record_error();
                p.responder.send(Response {
                    version: Some(version),
                    ..Response::err(
                        Some(id),
                        format!(
                            "dim mismatch: got {}, want {dim}",
                            row.len()
                        ),
                    )
                });
            }
        }
        // Score vectors are materialized once per batch iff anyone in
        // it asked (still ONE engine call); each response then carries
        // its own row's vector only if ITS request asked.
        let want_scores = ok.iter().any(|p| p.req.want_scores);
        match engine.eval_batch_ex(&rows, want_scores) {
            Ok(out) => {
                // If the engine returns fewer values than rows (engine
                // bug), the unmatched responders answer "worker
                // dropped" on drop — never silence.
                let scores = out.scores;
                for (i, (p, value)) in
                    ok.into_iter().zip(out.values).enumerate()
                {
                    let dur = p.enqueued.elapsed();
                    slo.record_ok(dur);
                    let id = p.req.id;
                    // Slice this row out of the flat matrix — the only
                    // per-request score allocation is for requests that
                    // actually asked.
                    let row_scores = if p.req.want_scores {
                        scores
                            .as_ref()
                            .and_then(|m| m.row(i))
                            .map(|s| s.to_vec())
                    } else {
                        None
                    };
                    p.responder.send(Response {
                        id: Some(id),
                        result: Ok(value),
                        scores: row_scores,
                        latency_us: dur.as_nanos() as f64 / 1e3,
                        epoch: None,
                        version: Some(version),
                    });
                }
            }
            Err(e) => {
                let msg = format!("engine error: {e}");
                for p in ok {
                    let id = p.req.id;
                    slo.record_error();
                    p.responder.send(Response {
                        version: Some(version),
                        ..Response::err(Some(id), msg.clone())
                    });
                }
            }
        }
    }

    /// Apply one FIFO run of `update` mutations.  Rows are validated
    /// per-request against the engine's update shape (dimension +
    /// class range) so one bad mutation is rejected alone, then the
    /// survivors go to the engine as ONE `apply_updates` batch whose
    /// publish flag is the OR of the run's — every ack then carries
    /// the plane epoch those updates are visible under.
    fn run_updates(
        engine: &mut dyn Engine,
        run: Vec<Pending>,
        slo: &LaneSlo,
        version: u64,
    ) {
        let Some((p_dim, c_n)) = engine.update_shape() else {
            for p in run {
                let id = p.req.id;
                slo.record_error();
                p.responder.send(Response {
                    version: Some(version),
                    ..Response::err(
                        Some(id),
                        "this backend does not support updates",
                    )
                });
            }
            return;
        };
        let mut ok = Vec::with_capacity(run.len());
        let mut ups = Vec::with_capacity(run.len());
        let mut publish = false;
        for mut p in run {
            let spec = p.req.update.expect("update run");
            let row = std::mem::take(&mut p.req.features);
            if row.len() != p_dim {
                let id = p.req.id;
                slo.record_error();
                p.responder.send(Response {
                    version: Some(version),
                    ..Response::err(
                        Some(id),
                        format!(
                            "update dim mismatch: got {}, want p = \
                             {p_dim} (updates are in the projected \
                             space)",
                            row.len()
                        ),
                    )
                });
                continue;
            }
            if spec.class >= c_n {
                let id = p.req.id;
                slo.record_error();
                p.responder.send(Response {
                    version: Some(version),
                    ..Response::err(
                        Some(id),
                        format!(
                            "update class {} out of C = {c_n}",
                            spec.class
                        ),
                    )
                });
                continue;
            }
            publish |= spec.publish;
            ups.push(UpdateRow {
                x: row,
                alpha: spec.alpha(),
                class: spec.class,
            });
            ok.push(p);
        }
        if ok.is_empty() {
            return;
        }
        match engine.apply_updates(&ups, publish) {
            Ok(ack) => {
                for p in ok {
                    let dur = p.enqueued.elapsed();
                    slo.record_ok(dur);
                    let id = p.req.id;
                    p.responder.send(Response {
                        id: Some(id),
                        result: Ok(0.0),
                        scores: None,
                        latency_us: dur.as_nanos() as f64 / 1e3,
                        epoch: Some(ack.epoch),
                        version: Some(version),
                    });
                }
            }
            Err(e) => {
                let msg = format!("update failed: {e}");
                for p in ok {
                    let id = p.req.id;
                    slo.record_error();
                    p.responder.send(Response {
                        version: Some(version),
                        ..Response::err(Some(id), msg.clone())
                    });
                }
            }
        }
    }

    /// Submit a request with an explicit response sink.
    ///
    /// Exactly one response is guaranteed to reach the sink: unknown
    /// lanes and backpressure are answered immediately (the error cases
    /// additionally return `Err` so callers can track rejections), and
    /// accepted requests carry a [`Responder`] whose drop guard answers
    /// `"worker dropped"` if the lane dies mid-flight.
    ///
    /// Hot-swap race: between reading the lane and submitting, a swap
    /// may replace it and close its batcher.  `Closed` from a lane the
    /// map no longer holds retries onto the replacement — the request
    /// lands on the NEW model, never in the void.
    pub fn submit_sink(
        &self,
        req: Request,
        sink: ResponseSink,
    ) -> Result<(), SubmitError> {
        let id = req.id;
        let responder = Responder::new(id, sink);
        let key = (req.model.clone(), req.backend);
        let mut lane = match self.lanes.read().unwrap().get(&key) {
            Some(l) => l.clone(),
            None => {
                // ORDERING: Relaxed — monotonic stat counter.
                self.rejected.fetch_add(1, Ordering::Relaxed);
                responder.send(Response::err(
                    Some(id),
                    format!(
                        "no lane for model={} backend={}",
                        key.0,
                        key.1.name()
                    ),
                ));
                return Ok(());
            }
        };
        let mut pending = Pending {
            req,
            enqueued: Instant::now(),
            responder,
        };
        loop {
            match lane.batcher.submit(pending) {
                Ok(()) => return Ok(()),
                Err((p, e)) => {
                    if matches!(e, SubmitError::Closed) {
                        if let Some(l2) =
                            self.lanes.read().unwrap().get(&key)
                        {
                            if !Arc::ptr_eq(l2, &lane) {
                                // The lane was swapped under us:
                                // resubmit to the replacement.
                                lane = l2.clone();
                                pending = p;
                                continue;
                            }
                        }
                    }
                    // ORDERING: Relaxed — monotonic stat counter.
                    self.rejected.fetch_add(1, Ordering::Relaxed);
                    p.responder.send(Response::err(
                        Some(id),
                        format!("backpressure: {e:?}"),
                    ));
                    return Err(e);
                }
            }
        }
    }

    /// Submit a request; the response arrives on the returned channel.
    /// On `Err` the (dropped) channel still received the backpressure
    /// response — in-process callers use the `Err` directly.
    pub fn submit(&self, req: Request) -> Result<Receiver<Response>, SubmitError> {
        let (tx, rx) = channel();
        self.submit_sink(req, ResponseSink::Channel(tx))?;
        Ok(rx)
    }

    /// Blocking convenience: submit and wait.
    pub fn call(&self, req: Request) -> Response {
        let id = req.id;
        match self.submit(req) {
            Ok(rx) => rx.recv().unwrap_or_else(|_| {
                Response::err(Some(id), "worker dropped")
            }),
            Err(e) => Response::err(Some(id), format!("rejected: {e:?}")),
        }
    }

    pub fn lane_stats(&self) -> Vec<(String, String, u64, u64, String)> {
        self.lanes
            .read()
            .unwrap()
            .iter()
            .map(|((m, k), lane)| {
                (
                    m.clone(),
                    k.name().to_string(),
                    // ORDERING: Relaxed — stat snapshot reads.
                    lane.batcher.submitted.load(Ordering::Relaxed),
                    lane.batcher.batches.load(Ordering::Relaxed), // ORDERING: see above
                    lane.slo.latency.summary(),
                )
            })
            .collect()
    }

    pub fn slo_of(&self, model: &str, kind: BackendKind)
        -> Option<Arc<LaneSlo>> {
        self.lanes
            .read()
            .unwrap()
            .get(&(model.to_string(), kind))
            .map(|l| l.slo.clone())
    }

    /// The current version of a lane (None when no such lane exists).
    pub fn version_of(&self, model: &str, kind: BackendKind)
        -> Option<u64> {
        self.lanes
            .read()
            .unwrap()
            .get(&(model.to_string(), kind))
            .map(|l| l.version)
    }

    /// Attach a remote shard set's counters to the `stats` verb under
    /// `model`.  Called during serve start, before the reactor runs.
    pub fn register_shard_stats(
        &self,
        model: &str,
        stats: Arc<RemoteShardStats>,
    ) {
        self.shard_stats
            .lock()
            .unwrap()
            .push((model.to_string(), stats));
    }

    /// Arm the hot-swap verb.  After this, a `{"id": N, "swap": {...}}`
    /// line loads and validates the named model on a dedicated admin
    /// thread (never the reactor), registers the replacement lane with
    /// `cfg`, and drains the old one — see [`Router::add_lane`].  The
    /// self-reference is weak: an in-flight admin thread cannot keep a
    /// torn-down router (and its worker threads) alive.
    pub fn enable_swap(self: &Arc<Self>, cfg: RouterConfig) {
        let _ = self.swap.set(SwapCtx {
            me: Arc::downgrade(self),
            cfg,
        });
    }

    /// The `stats` verb response: one JSON line with every lane's SLO
    /// counters and every registered remote shard set's replication
    /// counters.
    ///
    /// Schema:
    /// `{"id": N, "stats": {"rejected": R, "lanes": [{"model", "backend",
    /// "v", "submitted", "batches", "ok", "errors", "latency": {"n",
    /// "mean_us", "p50_us", "p99_us", "p999_us"}, "update": null |
    /// {"epoch", "updates", "publishes", "pending", "staleness_us"}},
    /// ...], "shards": [{"model", "shards": [per-shard objects with
    /// gathers/errors/hedges/failovers/reconnects/quarantines/discarded/
    /// latency and nested per-replica counters]}, ...]}}`.
    ///
    /// `update` is `null` for immutable lanes; for live lanes,
    /// `staleness_us` is the age of the oldest unpublished delta (the
    /// bounded-staleness surface — see `metrics::slo::UpdateSlo`).
    ///
    /// The error budget over a window at target availability `t` is
    /// `(ok + errors) × (1 − t) − errors`, diffing two snapshots —
    /// see `metrics::slo`.
    pub fn stats_line(&self, id: u64) -> String {
        let mut lanes: Vec<(String, &'static str, Arc<Lane>)> = self
            .lanes
            .read()
            .unwrap()
            .iter()
            .map(|((m, k), lane)| (m.clone(), k.name(), lane.clone()))
            .collect();
        lanes.sort_by(|a, b| (&a.0, a.1).cmp(&(&b.0, b.1)));
        let lanes = Json::Arr(
            lanes
                .into_iter()
                .map(|(m, k, lane)| {
                    json::obj(vec![
                        ("model", Json::Str(m)),
                        ("backend", Json::Str(k.to_string())),
                        ("v", Json::from_u64(lane.version)),
                        (
                            "submitted",
                            Json::from_u64(
                                lane.batcher
                                    .submitted
                                    // ORDERING: Relaxed — stat snapshot.
                                    .load(Ordering::Relaxed),
                            ),
                        ),
                        (
                            "batches",
                            Json::from_u64(
                                lane.batcher
                                    .batches
                                    // ORDERING: Relaxed — stat snapshot.
                                    .load(Ordering::Relaxed),
                            ),
                        ),
                        (
                            "ok",
                            Json::from_u64(lane.slo.ok_count()),
                        ),
                        (
                            "errors",
                            Json::from_u64(lane.slo.error_count()),
                        ),
                        (
                            "latency",
                            crate::metrics::slo::histogram_json(
                                &lane.slo.latency,
                            ),
                        ),
                        (
                            "update",
                            match lane.update.get() {
                                Some(u) => u.to_json(),
                                None => Json::Null,
                            },
                        ),
                    ])
                })
                .collect(),
        );
        let shards = Json::Arr(
            self.shard_stats
                .lock()
                .unwrap()
                .iter()
                .map(|(m, stats)| {
                    json::obj(vec![
                        ("model", Json::Str(m.clone())),
                        ("shards", stats.to_json()),
                    ])
                })
                .collect(),
        );
        json::obj(vec![
            ("id", Json::from_u64(id)),
            (
                "stats",
                json::obj(vec![
                    (
                        "rejected",
                        Json::from_u64(
                            // ORDERING: Relaxed — stat snapshot.
                            self.rejected.load(Ordering::Relaxed),
                        ),
                    ),
                    ("lanes", lanes),
                    ("shards", shards),
                ]),
            ),
        ])
        .to_string()
    }

    /// Graceful shutdown: close all lanes, join workers (drains queues).
    /// Also the signal-driven drain path — every queued request is
    /// answered before this returns.
    pub fn shutdown(&self) {
        let lanes: Vec<Arc<Lane>> = self
            .lanes
            .read()
            .unwrap()
            .values()
            .cloned()
            .collect();
        for lane in &lanes {
            lane.batcher.close();
        }
        for lane in &lanes {
            Self::drain_lane(lane);
        }
    }
}

impl Default for Router {
    fn default() -> Self {
        Self::new()
    }
}

/// What a validated swap loaded from disk, ready to become an engine
/// inside the new lane's worker.  Loading and validation happen on the
/// admin thread BEFORE the lane flips — a bad file answers an error
/// and the serving lane never notices.
#[cfg(target_os = "linux")]
enum SwapModel {
    Race(crate::sketch::RaceSketch),
    Fused(crate::sketch::FusedMultiSketch),
    Sharded(crate::shard::ShardedSketch),
}

/// Load the model a `swap` names, held to the same validators as the
/// load-time CLI paths (magic check, header validation, shard-set
/// re-validation against the recomputed plan).
#[cfg(target_os = "linux")]
fn load_swap_model(spec: &SwapSpec) -> anyhow::Result<SwapModel> {
    match spec.backend {
        BackendKind::Sketch => Ok(SwapModel::Race(
            crate::sketch::RaceSketch::load(&spec.path)?,
        )),
        BackendKind::Multiclass => Ok(SwapModel::Fused(
            crate::sketch::FusedMultiSketch::load(&spec.path)?,
        )),
        BackendKind::Sharded => {
            let sharded = if spec.shards > 0 {
                crate::shard::serde::load_sharded(&spec.path, spec.shards)?
            } else {
                crate::shard::serde::load_shard_set(&spec.path)?
            };
            Ok(SwapModel::Sharded(sharded))
        }
        other => anyhow::bail!(
            "backend {} is not hot-swappable (swap serves rs, mc, and \
             local sh lanes)",
            other.name()
        ),
    }
}

#[cfg(target_os = "linux")]
impl Router {
    /// Execute one `swap` verb: spawn the named admin thread, load +
    /// validate there, flip the lane, drain the old worker, answer
    /// `{"id": N, "swapped": {"model", "backend", "v"}}`.  This is the
    /// only thread the coordinator ever spawns outside `add_lane` —
    /// it exists exactly as long as one swap is in flight.
    fn spawn_swap(
        &self,
        rid: u64,
        spec: SwapSpec,
        sender: super::net::CompletionSender,
    ) {
        let Some(ctx) = self.swap.get() else {
            sender.send(Response::err(
                Some(rid),
                "swap is not enabled on this server",
            ));
            return;
        };
        let me = ctx.me.clone();
        let cfg = ctx.cfg.clone();
        std::thread::Builder::new()
            .name(format!("swap-{}", spec.model))
            .spawn(move || {
                let outcome = (|| -> anyhow::Result<u64> {
                    let router = me.upgrade().ok_or_else(|| {
                        anyhow::anyhow!("router is shutting down")
                    })?;
                    // Load + validate BEFORE touching the lane map: a
                    // failed load never flips, and the serving lane
                    // keeps answering on the old model throughout.
                    let model = load_swap_model(&spec)?;
                    let v = match model {
                        SwapModel::Race(sk) => router.add_lane(
                            &spec.model,
                            spec.backend,
                            move || {
                                Ok(Box::new(
                                    super::backend::SketchEngine::new(sk),
                                ) as _)
                            },
                            &cfg,
                        ),
                        SwapModel::Fused(fs) => router.add_lane(
                            &spec.model,
                            spec.backend,
                            move || {
                                Ok(Box::new(
                                    super::backend::MulticlassEngine::new(
                                        fs,
                                    ),
                                ) as _)
                            },
                            &cfg,
                        ),
                        SwapModel::Sharded(sh) => router.add_lane(
                            &spec.model,
                            spec.backend,
                            move || {
                                Ok(Box::new(
                                    super::backend::ShardedEngine::new(sh),
                                ) as _)
                            },
                            &cfg,
                        ),
                    };
                    Ok(v)
                })();
                match outcome {
                    Ok(v) => sender.send_line(
                        json::obj(vec![
                            ("id", Json::from_u64(rid)),
                            (
                                "swapped",
                                json::obj(vec![
                                    (
                                        "model",
                                        Json::Str(spec.model.clone()),
                                    ),
                                    (
                                        "backend",
                                        Json::Str(
                                            spec.backend
                                                .name()
                                                .to_string(),
                                        ),
                                    ),
                                    ("v", Json::from_u64(v)),
                                ]),
                            ),
                        ])
                        .to_string(),
                    ),
                    Err(e) => sender.send(Response::err(
                        Some(rid),
                        format!("swap failed: {e:#}"),
                    )),
                }
            })
            .expect("spawn swap admin thread");
    }
}

/// The inference plane behind the reactor: parse a request line, submit
/// it with the reactor completion sink.  Exactly one response per line:
/// parse failures answer immediately with a best-effort-recovered id,
/// accepted requests carry a [`Responder`] whose drop guard fires if
/// the lane dies, and unknown-lane/backpressure errors are answered by
/// `submit_sink` itself.  Admin verbs are recognized first: `stats`
/// (answered inline — counter loads only), then `swap` (handed to an
/// admin thread — never load files on the reactor).
#[cfg(target_os = "linux")]
impl super::net::LineHandler for Router {
    fn handle_line(
        &self,
        line: String,
        sender: super::net::CompletionSender,
    ) {
        use super::protocol::extract_id;
        // The stats verb is answered inline (counter loads + JSON
        // rendering only — no lane round-trip, no kernel work).
        if let Some(rid) = super::protocol::parse_stats_line(&line) {
            sender.send_line(self.stats_line(rid));
            return;
        }
        if let Some(swap) = super::protocol::parse_swap_line(&line) {
            match swap {
                Ok((rid, spec)) => self.spawn_swap(rid, spec, sender),
                Err(e) => sender.send(Response::err(
                    extract_id(&line),
                    format!("bad swap request: {e}"),
                )),
            }
            return;
        }
        match Request::parse_line(&line) {
            Ok(req) => {
                let _ = self
                    .submit_sink(req, ResponseSink::Reactor(sender));
            }
            Err(e) => sender.send(Response::err(
                extract_id(&line),
                format!("bad request: {e}"),
            )),
        }
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::{UpdateAck, UpdateRow};
    use crate::coordinator::protocol::UpdateSpec;

    /// Test engine: y = sum(x) (+ optional failure injection).
    struct SumEngine {
        dim: usize,
        fail: bool,
    }

    impl Engine for SumEngine {
        fn dim(&self) -> usize {
            self.dim
        }

        fn eval_batch(&mut self, rows: &[Vec<f32>])
            -> anyhow::Result<Vec<f32>> {
            if self.fail {
                anyhow::bail!("injected failure");
            }
            Ok(rows.iter().map(|r| r.iter().sum()).collect())
        }
    }

    fn mk_router(fail: bool) -> Router {
        let r = Router::new();
        r.add_lane(
            "m",
            BackendKind::Sketch,
            move || Ok(Box::new(SumEngine { dim: 3, fail }) as Box<dyn Engine>),
            &RouterConfig::default(),
        );
        r
    }

    fn req(id: u64, x: Vec<f32>) -> Request {
        Request {
            id,
            model: "m".into(),
            backend: BackendKind::Sketch,
            features: x,
            want_scores: false,
            update: None,
        }
    }

    fn upd_req(id: u64, x: Vec<f32>, spec: UpdateSpec) -> Request {
        Request {
            update: Some(spec),
            ..req(id, x)
        }
    }

    #[test]
    fn routes_and_answers() {
        let r = mk_router(false);
        let resp = r.call(req(1, vec![1.0, 2.0, 3.0]));
        assert_eq!(resp.id, Some(1));
        assert_eq!(resp.result.unwrap(), 6.0);
        assert!(resp.latency_us > 0.0);
        assert_eq!(resp.version, Some(1));
    }

    #[test]
    fn unknown_lane_gets_error_response() {
        let r = mk_router(false);
        let resp = r.call(Request {
            id: 9,
            model: "nope".into(),
            backend: BackendKind::Sketch,
            features: vec![1.0],
            want_scores: false,
            update: None,
        });
        assert!(resp.result.is_err());
        assert_eq!(r.rejected.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn dim_mismatch_isolated_within_batch() {
        let r = mk_router(false);
        let bad = r.call(req(1, vec![1.0]));
        assert!(bad.result.is_err());
        // Lane errors still carry the version-attribution handle.
        assert_eq!(bad.version, Some(1));
        let good = r.call(req(2, vec![1.0, 1.0, 1.0]));
        assert_eq!(good.result.unwrap(), 3.0);
    }

    #[test]
    fn engine_failure_reported_not_lost() {
        let r = mk_router(true);
        let resp = r.call(req(1, vec![1.0, 2.0, 3.0]));
        assert!(resp.result.unwrap_err().contains("injected"));
    }

    #[test]
    fn every_request_gets_exactly_one_response() {
        // The central no-loss/no-dup invariant under concurrency.
        let r = std::sync::Arc::new(mk_router(false));
        let n_threads = 8;
        let per_thread = 200u64;
        let mut handles = Vec::new();
        for t in 0..n_threads {
            let r = r.clone();
            handles.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                for i in 0..per_thread {
                    let id = t * per_thread + i;
                    let resp =
                        r.call(req(id, vec![id as f32, 0.0, 1.0]));
                    assert_eq!(resp.id, Some(id));
                    got.push((id, resp.result.unwrap()));
                }
                got
            }));
        }
        let mut all = std::collections::HashMap::new();
        for h in handles {
            for (id, v) in h.join().unwrap() {
                assert!(all.insert(id, v).is_none(), "dup id {id}");
                assert_eq!(v, id as f32 + 1.0);
            }
        }
        assert_eq!(all.len(), (n_threads * per_thread) as usize);
    }

    /// Engine that dies (panics) on every eval — models a lane tearing
    /// down with requests in flight.
    struct DyingEngine;

    impl Engine for DyingEngine {
        fn dim(&self) -> usize {
            3
        }

        fn eval_batch(&mut self, _rows: &[Vec<f32>])
            -> anyhow::Result<Vec<f32>> {
            panic!("lane died mid-flight");
        }
    }

    #[test]
    fn lane_teardown_mid_flight_answers_every_request() {
        // The exactly-one-response invariant through engine/lane
        // teardown: the drained batch's responders fire during the
        // worker's unwind, queued-but-undrained requests fire when the
        // router (and with it the batcher queue) is dropped.  The seed
        // lost all of these silently.
        let r = Router::new();
        r.add_lane(
            "m",
            BackendKind::Sketch,
            move || Ok(Box::new(DyingEngine) as Box<dyn Engine>),
            &RouterConfig::default(),
        );
        let mut rxs = Vec::new();
        for i in 0..40u64 {
            if let Ok(rx) = r.submit(req(i, vec![0.0, 0.0, 1.0])) {
                rxs.push((i, rx));
            }
        }
        assert!(!rxs.is_empty());
        drop(r); // shutdown: close + join dead worker, drop the queue
        for (i, rx) in rxs {
            let resp = rx
                .recv_timeout(std::time::Duration::from_secs(5))
                .expect("a response must arrive, not channel-drop");
            assert_eq!(resp.id, Some(i));
            assert!(
                resp.result.unwrap_err().contains("worker dropped"),
                "request {i} must get the worker-dropped error"
            );
        }
    }

    #[test]
    fn truncated_engine_output_still_answers_all() {
        // An engine that returns fewer values than rows: matched rows
        // get answers, the rest get worker-dropped — never silence.
        struct ShortEngine;
        impl Engine for ShortEngine {
            fn dim(&self) -> usize {
                3
            }
            fn eval_batch(&mut self, rows: &[Vec<f32>])
                -> anyhow::Result<Vec<f32>> {
                Ok(rows[..rows.len() / 2]
                    .iter()
                    .map(|r| r.iter().sum())
                    .collect())
            }
        }
        let r = Router::new();
        let cfg = RouterConfig {
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: std::time::Duration::from_secs(30),
                queue_cap: 64,
            },
        };
        r.add_lane(
            "m",
            BackendKind::Sketch,
            move || Ok(Box::new(ShortEngine) as Box<dyn Engine>),
            &cfg,
        );
        let rxs: Vec<_> = (0..8u64)
            .map(|i| (i, r.submit(req(i, vec![1.0, 2.0, 3.0])).unwrap()))
            .collect();
        let mut answered = 0;
        let mut dropped = 0;
        for (i, rx) in rxs {
            let resp = rx
                .recv_timeout(std::time::Duration::from_secs(5))
                .unwrap();
            assert_eq!(resp.id, Some(i));
            match resp.result {
                Ok(v) => {
                    assert_eq!(v, 6.0);
                    answered += 1;
                }
                Err(e) => {
                    assert!(e.contains("worker dropped"));
                    dropped += 1;
                }
            }
        }
        assert_eq!(answered, 4);
        assert_eq!(dropped, 4);
    }

    #[test]
    fn stats_track_submissions() {
        let r = mk_router(false);
        for i in 0..10 {
            let _ = r.call(req(i, vec![0.0, 0.0, 0.0]));
        }
        let stats = r.lane_stats();
        assert_eq!(stats.len(), 1);
        assert_eq!(stats[0].2, 10); // submitted
        assert!(stats[0].3 >= 1); // batches
    }

    #[test]
    fn stats_line_reports_slo_counters_as_json() {
        let r = mk_router(false);
        for i in 0..5 {
            let _ = r.call(req(i, vec![0.0, 0.0, 0.0]));
        }
        // One dim-mismatch error charged to the lane's budget.
        let bad = r.call(req(99, vec![1.0]));
        assert!(bad.result.is_err());
        r.register_shard_stats(
            "m",
            Arc::new(RemoteShardStats::new(&[vec![
                "a0".to_string(),
                "a1".to_string(),
            ]])),
        );
        let line = r.stats_line(31);
        let j = json::parse(&line).unwrap();
        assert_eq!(j.get("id").unwrap().as_u64(), Some(31));
        let stats = j.get("stats").unwrap();
        assert_eq!(stats.get("rejected").unwrap().as_u64(), Some(0));
        let lanes = stats.get("lanes").unwrap().as_arr().unwrap();
        assert_eq!(lanes.len(), 1);
        assert_eq!(lanes[0].get("model").unwrap().as_str(), Some("m"));
        assert_eq!(lanes[0].get("v").unwrap().as_u64(), Some(1));
        assert_eq!(lanes[0].get("ok").unwrap().as_u64(), Some(5));
        assert_eq!(lanes[0].get("errors").unwrap().as_u64(), Some(1));
        // SumEngine is immutable: its update surface is null.
        assert!(matches!(lanes[0].get("update"), Some(Json::Null)));
        let lat = lanes[0].get("latency").unwrap();
        assert_eq!(lat.get("n").unwrap().as_u64(), Some(5));
        assert!(lat.get("p999_us").unwrap().as_f64().unwrap() > 0.0);
        let shards = stats.get("shards").unwrap().as_arr().unwrap();
        assert_eq!(shards.len(), 1);
        assert_eq!(
            shards[0].get("model").unwrap().as_str(),
            Some("m")
        );
        let per_shard =
            shards[0].get("shards").unwrap().as_arr().unwrap();
        assert_eq!(per_shard.len(), 1);
        assert_eq!(
            per_shard[0]
                .get("replicas")
                .unwrap()
                .as_arr()
                .unwrap()
                .len(),
            2
        );
    }

    #[test]
    fn lane_slo_exposed_for_lookup() {
        let r = mk_router(false);
        let _ = r.call(req(1, vec![0.0, 0.0, 0.0]));
        let slo = r.slo_of("m", BackendKind::Sketch).unwrap();
        assert_eq!(slo.ok_count(), 1);
        assert!(r.slo_of("nope", BackendKind::Sketch).is_none());
    }

    /// Mutable test engine: y = sum(x) + bias, where updates move the
    /// bias by `alpha · x[0]` — enough structure to verify routing,
    /// validation, publish plumbing, and FIFO ordering.
    struct UpdEngine {
        bias: f32,
        epoch: u64,
        slo: Arc<UpdateSlo>,
    }

    impl UpdEngine {
        fn new() -> UpdEngine {
            UpdEngine {
                bias: 0.0,
                epoch: 0,
                slo: Arc::new(UpdateSlo::new()),
            }
        }
    }

    impl Engine for UpdEngine {
        fn dim(&self) -> usize {
            2
        }

        fn eval_batch(&mut self, rows: &[Vec<f32>])
            -> anyhow::Result<Vec<f32>> {
            let b = self.bias;
            Ok(rows.iter().map(|r| r.iter().sum::<f32>() + b).collect())
        }

        fn update_shape(&self) -> Option<(usize, usize)> {
            Some((2, 3))
        }

        fn apply_updates(&mut self, ups: &[UpdateRow], publish: bool)
            -> anyhow::Result<UpdateAck> {
            for u in ups {
                self.bias += u.alpha * u.x[0];
                self.slo.record_update(1);
            }
            if publish {
                self.epoch += 1;
                self.slo.record_publish(self.epoch);
            }
            Ok(UpdateAck { epoch: self.epoch, pending: 0 })
        }

        fn plane_stats(&self) -> Option<Arc<UpdateSlo>> {
            Some(self.slo.clone())
        }
    }

    fn upd_router() -> Router {
        let r = Router::new();
        r.add_lane(
            "m",
            BackendKind::Sketch,
            || Ok(Box::new(UpdEngine::new()) as Box<dyn Engine>),
            &RouterConfig::default(),
        );
        r
    }

    #[test]
    fn updates_route_validate_and_ack_with_epoch() {
        let r = upd_router();
        // A valid update: acked with the (published) epoch + version.
        let ack = r.call(upd_req(
            1,
            vec![2.0, 0.0],
            UpdateSpec {
                weight: 3.0,
                class: 1,
                delete: false,
                publish: true,
            },
        ));
        assert_eq!(ack.result.as_ref().unwrap(), &0.0);
        assert_eq!(ack.epoch, Some(1));
        assert_eq!(ack.version, Some(1));
        // The mutation is visible to a later query on the same lane
        // (FIFO ordering): bias moved by 3 · 2 = 6.
        let q = r.call(req(2, vec![1.0, 1.0]));
        assert_eq!(q.result.unwrap(), 8.0);
        assert_eq!(q.epoch, None);
        // Per-row validation: wrong dim and out-of-range class answer
        // alone, without poisoning the lane.
        let bad = r.call(upd_req(
            3,
            vec![1.0],
            UpdateSpec {
                weight: 1.0,
                class: 0,
                delete: false,
                publish: false,
            },
        ));
        assert!(bad.result.unwrap_err().contains("update dim"), "dim");
        let bad = r.call(upd_req(
            4,
            vec![1.0, 0.0],
            UpdateSpec {
                weight: 1.0,
                class: 7,
                delete: false,
                publish: false,
            },
        ));
        assert!(bad.result.unwrap_err().contains("class 7"), "class");
        let q = r.call(req(5, vec![0.0, 0.0]));
        assert_eq!(q.result.unwrap(), 6.0);
        // The lane's update surface shows up in the stats line.
        let j = json::parse(&r.stats_line(9)).unwrap();
        let lanes = j
            .get("stats")
            .unwrap()
            .get("lanes")
            .unwrap()
            .as_arr()
            .unwrap();
        let upd = lanes[0].get("update").unwrap();
        assert_eq!(upd.get("updates").unwrap().as_u64(), Some(1));
        assert_eq!(upd.get("epoch").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn immutable_lane_rejects_updates_with_version() {
        let r = mk_router(false);
        let resp = r.call(upd_req(
            1,
            vec![0.0, 0.0, 0.0],
            UpdateSpec {
                weight: 1.0,
                class: 0,
                delete: false,
                publish: false,
            },
        ));
        let err = resp.result.unwrap_err();
        assert!(err.contains("does not support updates"), "{err}");
        assert_eq!(resp.version, Some(1));
    }

    #[test]
    fn lane_replacement_bumps_version_and_loses_nothing() {
        // The hot-swap primitive at the router level: re-registering a
        // key replaces the lane; responses are attributable to exactly
        // one version, and the old lane drains (its queued requests
        // answer on the OLD engine) before add_lane returns.
        let r = Router::new();
        r.add_lane(
            "m",
            BackendKind::Sketch,
            || Ok(Box::new(SumEngine { dim: 3, fail: false }) as _),
            &RouterConfig::default(),
        );
        let v1 = r.call(req(1, vec![1.0, 1.0, 1.0]));
        assert_eq!(v1.result.unwrap(), 3.0);
        assert_eq!(v1.version, Some(1));
        assert_eq!(
            r.version_of("m", BackendKind::Sketch),
            Some(1)
        );
        // Replace with an engine whose answers are distinguishable.
        let v2 = r.add_lane(
            "m",
            BackendKind::Sketch,
            || Ok(Box::new(UpdEngine::new()) as _),
            &RouterConfig::default(),
        );
        assert_eq!(v2, 2);
        assert_eq!(
            r.version_of("m", BackendKind::Sketch),
            Some(2)
        );
        let resp = r.call(req(2, vec![1.0, 1.0]));
        assert_eq!(resp.result.unwrap(), 2.0);
        assert_eq!(resp.version, Some(2));
    }

    #[test]
    fn submit_retries_onto_swapped_lane_when_old_closed() {
        // The submit/swap race: a submitter holding the OLD lane Arc
        // must land on the replacement, not answer backpressure.
        let r = Router::new();
        r.add_lane(
            "m",
            BackendKind::Sketch,
            || Ok(Box::new(SumEngine { dim: 3, fail: false }) as _),
            &RouterConfig::default(),
        );
        // Grab the old lane the way submit_sink does...
        let old = r
            .lanes
            .read()
            .unwrap()
            .get(&("m".to_string(), BackendKind::Sketch))
            .unwrap()
            .clone();
        // ...swap underneath it (add_lane joins the old worker)...
        r.add_lane(
            "m",
            BackendKind::Sketch,
            || Ok(Box::new(SumEngine { dim: 3, fail: false }) as _),
            &RouterConfig::default(),
        );
        // ...then prove the old batcher reports Closed while the
        // router-level submit still answers from the new lane.
        let (tx, _rx) = channel();
        let p = Pending {
            req: req(7, vec![1.0, 1.0, 1.0]),
            enqueued: Instant::now(),
            responder: Responder::new(7, ResponseSink::Channel(tx)),
        };
        match old.batcher.submit(p) {
            Err((_, SubmitError::Closed)) => {}
            _ => panic!("old lane's batcher must be closed after swap"),
        }
        let resp = r.call(req(8, vec![1.0, 1.0, 1.0]));
        assert_eq!(resp.result.unwrap(), 3.0);
        assert_eq!(resp.version, Some(2));
    }

    #[test]
    fn interleaved_updates_and_queries_stay_fifo() {
        // One pipelined burst mixing verbs: every query must observe
        // exactly the updates submitted before it (read-your-writes
        // through the run-splitting batcher drain).
        let r = std::sync::Arc::new(upd_router());
        let mut rxs = Vec::new();
        let mut want_bias = 0.0f32;
        let mut wants = Vec::new();
        for i in 0..60u64 {
            if i % 3 == 0 {
                let w = (i / 3 + 1) as f32;
                rxs.push(r
                    .submit(upd_req(
                        i,
                        vec![1.0, 0.0],
                        UpdateSpec {
                            weight: w,
                            class: 0,
                            delete: false,
                            publish: i % 2 == 0,
                        },
                    ))
                    .unwrap());
                want_bias += w;
                wants.push(None);
            } else {
                rxs.push(r.submit(req(i, vec![0.0, 0.0])).unwrap());
                wants.push(Some(want_bias));
            }
        }
        for (i, (rx, want)) in rxs.into_iter().zip(wants).enumerate() {
            let resp = rx
                .recv_timeout(std::time::Duration::from_secs(5))
                .unwrap();
            let got = resp.result.unwrap();
            if let Some(w) = want {
                assert_eq!(got, w, "query {i} saw a stale/early plane");
            }
        }
    }

    #[test]
    fn zero_sample_lane_reports_empty_quantiles() {
        // Satellite: a lane that has served nothing must report n=0
        // and 0.0 quantiles — not NaN, not garbage.
        let r = mk_router(false);
        let j = json::parse(&r.stats_line(1)).unwrap();
        let lanes = j
            .get("stats")
            .unwrap()
            .get("lanes")
            .unwrap()
            .as_arr()
            .unwrap();
        let lat = lanes[0].get("latency").unwrap();
        assert_eq!(lat.get("n").unwrap().as_u64(), Some(0));
        for q in ["p50_us", "p99_us", "p999_us", "mean_us"] {
            assert_eq!(
                lat.get(q).unwrap().as_f64(),
                Some(0.0),
                "{q} of an empty lane"
            );
        }
    }

    #[test]
    fn single_sample_p999_equals_the_sample_bucket() {
        // With one sample every quantile collapses to that sample's
        // bucket — p999 in particular must not read past the end.
        let r = mk_router(false);
        let _ = r.call(req(1, vec![0.0, 0.0, 0.0]));
        let j = json::parse(&r.stats_line(2)).unwrap();
        let lanes = j
            .get("stats")
            .unwrap()
            .get("lanes")
            .unwrap()
            .as_arr()
            .unwrap();
        let lat = lanes[0].get("latency").unwrap();
        assert_eq!(lat.get("n").unwrap().as_u64(), Some(1));
        let p50 = lat.get("p50_us").unwrap().as_f64().unwrap();
        let p999 = lat.get("p999_us").unwrap().as_f64().unwrap();
        assert!(p50 > 0.0);
        assert_eq!(p50, p999, "one sample: all quantiles coincide");
    }

    #[test]
    fn stats_counters_are_monotonic_across_calls() {
        // Satellite: two consecutive stats lines — counters never go
        // backwards (the error-budget math diffs snapshots).
        let r = upd_router();
        let read = |line: &str| -> (u64, u64, u64) {
            let j = json::parse(line).unwrap();
            let stats = j.get("stats").unwrap();
            let lane = &stats.get("lanes").unwrap().as_arr().unwrap()[0];
            (
                lane.get("submitted").unwrap().as_u64().unwrap(),
                lane.get("ok").unwrap().as_u64().unwrap(),
                lane.get("update")
                    .unwrap()
                    .get("updates")
                    .unwrap()
                    .as_u64()
                    .unwrap(),
            )
        };
        let _ = r.call(req(1, vec![0.0, 0.0]));
        let _ = r.call(upd_req(
            2,
            vec![1.0, 0.0],
            UpdateSpec {
                weight: 1.0,
                class: 0,
                delete: false,
                publish: true,
            },
        ));
        let a = read(&r.stats_line(10));
        let _ = r.call(req(3, vec![0.0, 0.0]));
        let _ = r.call(upd_req(
            4,
            vec![1.0, 0.0],
            UpdateSpec {
                weight: 1.0,
                class: 0,
                delete: false,
                publish: false,
            },
        ));
        let b = read(&r.stats_line(11));
        assert!(b.0 >= a.0 && b.1 >= a.1 && b.2 >= a.2,
                "{a:?} -> {b:?}");
        assert_eq!(b.0, a.0 + 2);
        assert_eq!(b.1, a.1 + 2);
        assert_eq!(b.2, a.2 + 1);
    }
}
