//! TCP JSON-line server on top of the router.
//!
//! Default mode (Linux) is the epoll reactor in [`super::net`]: one
//! event-loop thread handles accept, framing, submission, and response
//! write-back for every connection — the process thread count stays
//! fixed at reactor + lane workers + worker pool regardless of how many
//! connections or requests are in flight.  The reactor also fixes the
//! seed's front-end bugs: a thread spawned per in-flight request, idle
//! connections that never observed the stop flag (blocked in
//! `reader.lines()`), and unbounded line buffering that let a
//! newline-free stream OOM the process.
//!
//! `bind_legacy` (CLI: `serve --threads-legacy`) keeps the seed's
//! thread-per-connection loop as a one-release escape hatch; it is also
//! the fallback on non-Linux targets.  The legacy loop shares the
//! router-side fixes (exactly-one-response guarantee, best-effort id
//! recovery on malformed lines) but retains its per-connection threads
//! and unbounded line buffering.

use super::protocol::{extract_id, Request, Response};
use super::router::Router;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};

/// Which front-end loop `serve` runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeMode {
    /// Epoll reactor (Linux): fixed thread count, line cap, prompt
    /// stop.
    Reactor,
    /// Seed-style thread-per-connection loop (escape hatch; the only
    /// mode on non-Linux targets).
    ThreadsLegacy,
}

pub struct Server {
    router: Arc<Router>,
    listener: TcpListener,
    stop: Arc<AtomicBool>,
    pub connections: Arc<AtomicU64>,
    mode: ServeMode,
}

impl Server {
    /// Bind to an address ("127.0.0.1:0" for an ephemeral port) in the
    /// default mode (reactor on Linux, legacy elsewhere).
    pub fn bind(router: Arc<Router>, addr: &str) -> anyhow::Result<Self> {
        Self::bind_with_mode(router, addr, ServeMode::Reactor)
    }

    /// Bind with the legacy thread-per-connection loop.
    pub fn bind_legacy(
        router: Arc<Router>,
        addr: &str,
    ) -> anyhow::Result<Self> {
        Self::bind_with_mode(router, addr, ServeMode::ThreadsLegacy)
    }

    pub fn bind_with_mode(
        router: Arc<Router>,
        addr: &str,
        mode: ServeMode,
    ) -> anyhow::Result<Self> {
        // Off Linux there is no epoll: coerce to the legacy loop so
        // `mode()` (and everything that reports it — the serve banner,
        // BENCH_server.json rows) reflects what actually runs.
        #[cfg(not(target_os = "linux"))]
        let mode = ServeMode::ThreadsLegacy;
        let listener = TcpListener::bind(addr)?;
        Ok(Self {
            router,
            listener,
            stop: Arc::new(AtomicBool::new(false)),
            connections: Arc::new(AtomicU64::new(0)),
            mode,
        })
    }

    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.listener.local_addr().unwrap()
    }

    pub fn mode(&self) -> ServeMode {
        self.mode
    }

    /// Serve until `stop_handle` flips; call from a dedicated thread.
    /// The reactor observes the flag within ~50 ms even when every
    /// connection is idle and closes them on the way out.
    pub fn serve(&self) {
        #[cfg(target_os = "linux")]
        if self.mode == ServeMode::Reactor {
            match super::net::Reactor::new(
                self.router.clone(),
                &self.listener,
                self.stop.clone(),
                self.connections.clone(),
            ) {
                Ok(mut reactor) => {
                    reactor.run();
                    return;
                }
                Err(e) => {
                    eprintln!(
                        "reactor init failed ({e}); falling back to the \
                         legacy thread-per-connection loop"
                    );
                }
            }
        }
        self.serve_legacy();
    }

    pub fn stop_handle(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }

    /// The seed's accept loop (one thread per connection, one writer
    /// thread per connection, one forwarder thread per in-flight
    /// request).  Kept verbatim-in-spirit as the `--threads-legacy`
    /// escape hatch and the non-Linux fallback.
    fn serve_legacy(&self) {
        self.listener.set_nonblocking(true).ok();
        loop {
            if self.stop.load(Ordering::Acquire) {
                break;
            }
            match self.listener.accept() {
                Ok((stream, _)) => {
                    self.connections.fetch_add(1, Ordering::Relaxed);
                    let router = self.router.clone();
                    let stop = self.stop.clone();
                    std::thread::spawn(move || {
                        handle_conn_legacy(stream, router, stop);
                    });
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(_) => break,
            }
        }
    }
}

fn handle_conn_legacy(
    stream: TcpStream,
    router: Arc<Router>,
    stop: Arc<AtomicBool>,
) {
    stream.set_nodelay(true).ok();
    let reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    // Writer thread: serializes responses from all in-flight requests.
    let (out_tx, out_rx) = mpsc::channel::<Response>();
    let mut wstream = stream;
    let writer = std::thread::spawn(move || {
        for resp in out_rx {
            let mut line = resp.to_line();
            line.push('\n');
            if wstream.write_all(line.as_bytes()).is_err() {
                break;
            }
        }
    });

    for line in reader.lines() {
        if stop.load(Ordering::Acquire) {
            break;
        }
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        match Request::parse_line(&line) {
            Ok(req) => {
                let id = req.id;
                match router.submit(req) {
                    Ok(rx) => {
                        // Forward the response asynchronously.  The
                        // responder guarantees the channel always
                        // yields exactly one response, but keep a
                        // belt-and-braces error for a dropped sender.
                        let out_tx = out_tx.clone();
                        std::thread::spawn(move || {
                            let resp = rx.recv().unwrap_or(Response {
                                id: Some(id),
                                result: Err("worker dropped".into()),
                                latency_us: 0.0,
                            });
                            let _ = out_tx.send(resp);
                        });
                    }
                    Err(e) => {
                        let _ = out_tx.send(Response {
                            id: Some(id),
                            result: Err(format!("backpressure: {e:?}")),
                            latency_us: 0.0,
                        });
                    }
                }
            }
            Err(e) => {
                let _ = out_tx.send(Response {
                    id: extract_id(&line),
                    result: Err(format!("bad request: {e}")),
                    latency_us: 0.0,
                });
            }
        }
    }
    drop(out_tx);
    let _ = writer.join();
}
