//! TCP JSON-line server on top of the router.
//!
//! On Linux the ONLY front-end is the epoll reactor in [`super::net`]:
//! one event-loop thread handles accept, framing, submission, and
//! response write-back for every connection — the process thread count
//! stays fixed at reactor + lane workers + worker pool regardless of
//! how many connections or requests are in flight.  The reactor also
//! fixed the seed front-end's bugs: a thread spawned per in-flight
//! request, idle connections that never observed the stop flag
//! (blocked in `reader.lines()`), and unbounded line buffering that let
//! a newline-free stream OOM the process.
//!
//! The seed's thread-per-connection loop survived one release as the
//! `serve --threads-legacy` escape hatch (PR 3) and has now been
//! removed on Linux; it remains ONLY as the non-Linux fallback
//! (`ServeMode::ThreadsFallback`), compiled out of Linux builds
//! entirely.  Its behavioral contracts (exactly-one-response,
//! best-effort id recovery, blank-line tolerance) are locked by
//! `tests/server_reactor.rs` against the reactor.

use super::router::Router;
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, AtomicU64};
use std::sync::Arc;

#[cfg(not(target_os = "linux"))]
use super::protocol::{extract_id, Request, Response};
#[cfg(not(target_os = "linux"))]
use std::io::{BufRead, BufReader, Write};
#[cfg(not(target_os = "linux"))]
use std::net::TcpStream;
#[cfg(not(target_os = "linux"))]
use std::sync::atomic::Ordering;
#[cfg(not(target_os = "linux"))]
use std::sync::mpsc;

/// Which front-end loop `serve` runs.  Not user-selectable: Linux
/// always runs the reactor, everything else always runs the fallback.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeMode {
    /// Epoll reactor (Linux): fixed thread count, line cap, prompt
    /// stop.
    Reactor,
    /// Thread-per-connection fallback — the only mode on non-Linux
    /// targets, where there is no epoll.
    ThreadsFallback,
}

pub struct Server {
    /// What the reactor serves: any line-protocol service.  The
    /// inference plane passes the router (which implements
    /// `LineHandler`); `shard-serve` passes a
    /// `shard::remote::ShardService`.
    #[cfg(target_os = "linux")]
    handler: Arc<dyn super::net::LineHandler>,
    /// The non-Linux fallback loop is inference-plane only, so it keeps
    /// the concrete router.
    #[cfg(not(target_os = "linux"))]
    router: Arc<Router>,
    listener: TcpListener,
    stop: Arc<AtomicBool>,
    pub connections: Arc<AtomicU64>,
    mode: ServeMode,
    /// Wire options handed to the reactor (framing mode, frame cap,
    /// write cap, reject counters).  Unused by the non-Linux fallback,
    /// which is JSON-lines only.
    #[cfg(target_os = "linux")]
    opts: super::net::NetOptions,
}

impl Server {
    /// Bind the inference plane to an address ("127.0.0.1:0" for an
    /// ephemeral port).  The mode is decided by the target OS (see
    /// [`ServeMode`]).  The inference wire stays JSON lines.
    pub fn bind(router: Arc<Router>, addr: &str) -> anyhow::Result<Self> {
        #[cfg(target_os = "linux")]
        {
            Self::bind_handler_opts(
                router,
                addr,
                super::net::NetOptions::default(),
            )
        }
        #[cfg(not(target_os = "linux"))]
        {
            let listener = TcpListener::bind(addr)?;
            Ok(Self {
                router,
                listener,
                stop: Arc::new(AtomicBool::new(false)),
                connections: Arc::new(AtomicU64::new(0)),
                mode: ServeMode::ThreadsFallback,
            })
        }
    }

    /// Bind an arbitrary service behind the reactor with default wire
    /// options (Linux only — the fallback loop is router-specific).
    #[cfg(target_os = "linux")]
    pub fn bind_handler(
        handler: Arc<dyn super::net::LineHandler>,
        addr: &str,
    ) -> anyhow::Result<Self> {
        Self::bind_handler_opts(handler, addr, super::net::NetOptions::default())
    }

    /// Bind an arbitrary service behind the reactor with explicit wire
    /// options.  This is how the shard plane serves: same accept path,
    /// framing, caps, and completion machinery as the inference plane,
    /// but with `WireMode::Auto` so one port answers binary frames and
    /// JSON lines alike.
    #[cfg(target_os = "linux")]
    pub fn bind_handler_opts(
        handler: Arc<dyn super::net::LineHandler>,
        addr: &str,
        opts: super::net::NetOptions,
    ) -> anyhow::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        Ok(Self {
            handler,
            listener,
            stop: Arc::new(AtomicBool::new(false)),
            connections: Arc::new(AtomicU64::new(0)),
            mode: ServeMode::Reactor,
            opts,
        })
    }

    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.listener.local_addr().unwrap()
    }

    pub fn mode(&self) -> ServeMode {
        self.mode
    }

    /// Serve until `stop_handle` flips; call from a dedicated thread.
    /// The reactor observes the flag within ~50 ms even when every
    /// connection is idle and closes them on the way out.
    ///
    /// With the legacy loop gone there is nothing to fall back to on
    /// Linux: a reactor that cannot initialize (e.g. epoll fd
    /// exhaustion) is a hard `Err`, so the CLI exits nonzero instead
    /// of printing a banner and quietly serving nothing.
    pub fn serve(&self) -> anyhow::Result<()> {
        #[cfg(target_os = "linux")]
        {
            use anyhow::Context as _;
            let mut reactor = super::net::Reactor::new(
                self.handler.clone(),
                &self.listener,
                self.stop.clone(),
                self.connections.clone(),
                self.opts.clone(),
            )
            .context("reactor init failed")?;
            reactor.run();
            Ok(())
        }
        #[cfg(not(target_os = "linux"))]
        {
            self.serve_fallback();
            Ok(())
        }
    }

    pub fn stop_handle(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }

    /// Thread-per-connection accept loop — the non-Linux fallback
    /// (there is no epoll to build the reactor on).  Shares the
    /// router-side guarantees (exactly-one-response, id recovery) but
    /// keeps per-connection threads and unbounded line buffering.
    #[cfg(not(target_os = "linux"))]
    fn serve_fallback(&self) {
        self.listener.set_nonblocking(true).ok();
        loop {
            // ORDERING: Acquire pairs with shutdown's Release store.
            if self.stop.load(Ordering::Acquire) {
                break;
            }
            match self.listener.accept() {
                Ok((stream, _)) => {
                    // ORDERING: Relaxed — monotonic stat counter.
                    self.connections.fetch_add(1, Ordering::Relaxed);
                    let router = self.router.clone();
                    let stop = self.stop.clone();
                    std::thread::spawn(move || {
                        handle_conn_fallback(stream, router, stop);
                    });
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(_) => break,
            }
        }
    }
}

#[cfg(not(target_os = "linux"))]
fn handle_conn_fallback(
    stream: TcpStream,
    router: Arc<Router>,
    stop: Arc<AtomicBool>,
) {
    stream.set_nodelay(true).ok();
    let reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    // Writer thread: serializes response lines from all in-flight
    // requests.  Carries raw strings (not `Response`) so the `stats`
    // verb — whose reply is not a protocol `Response` — shares the
    // same ordered write path.
    let (out_tx, out_rx) = mpsc::channel::<String>();
    let mut wstream = stream;
    let writer = std::thread::spawn(move || {
        for mut line in out_rx {
            line.push('\n');
            if wstream.write_all(line.as_bytes()).is_err() {
                break;
            }
        }
    });

    for line in reader.lines() {
        // ORDERING: Acquire pairs with shutdown's Release store.
        if stop.load(Ordering::Acquire) {
            break;
        }
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        // `stats` verb: answered inline from the router's SLO counters,
        // same as the reactor front-end.
        if let Some(rid) = super::protocol::parse_stats_line(&line) {
            let _ = out_tx.send(router.stats_line(rid));
            continue;
        }
        match Request::parse_line(&line) {
            Ok(req) => {
                let id = req.id;
                match router.submit(req) {
                    Ok(rx) => {
                        // Forward the response asynchronously.  The
                        // responder guarantees the channel always
                        // yields exactly one response, but keep a
                        // belt-and-braces error for a dropped sender.
                        let out_tx = out_tx.clone();
                        std::thread::spawn(move || {
                            let resp = rx.recv().unwrap_or_else(|_| {
                                Response::err(Some(id), "worker dropped")
                            });
                            let _ = out_tx.send(resp.to_line());
                        });
                    }
                    Err(e) => {
                        let _ = out_tx.send(
                            Response::err(
                                Some(id),
                                format!("backpressure: {e:?}"),
                            )
                            .to_line(),
                        );
                    }
                }
            }
            Err(e) => {
                let _ = out_tx.send(
                    Response::err(
                        extract_id(&line),
                        format!("bad request: {e}"),
                    )
                    .to_line(),
                );
            }
        }
    }
    drop(out_tx);
    let _ = writer.join();
}
