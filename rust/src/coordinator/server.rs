//! TCP JSON-line server on top of the router.
//!
//! One OS thread per connection (edge-scale concurrency); requests stream
//! in as JSON lines, responses stream out as they complete (a per-
//! connection writer thread serializes them).  Malformed lines produce an
//! error response with id 0 rather than killing the connection; queue-full
//! backpressure is surfaced as an error response for that id.

use super::protocol::{Request, Response};
use super::router::Router;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};

pub struct Server {
    router: Arc<Router>,
    listener: TcpListener,
    stop: Arc<AtomicBool>,
    pub connections: Arc<AtomicU64>,
}

impl Server {
    /// Bind to an address ("127.0.0.1:0" for an ephemeral port).
    pub fn bind(router: Arc<Router>, addr: &str) -> anyhow::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        Ok(Self {
            router,
            listener,
            stop: Arc::new(AtomicBool::new(false)),
            connections: Arc::new(AtomicU64::new(0)),
        })
    }

    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.listener.local_addr().unwrap()
    }

    /// Serve until `stop_handle` flips; call from a dedicated thread.
    pub fn serve(&self) {
        self.listener.set_nonblocking(true).ok();
        loop {
            if self.stop.load(Ordering::Acquire) {
                break;
            }
            match self.listener.accept() {
                Ok((stream, _)) => {
                    self.connections.fetch_add(1, Ordering::Relaxed);
                    let router = self.router.clone();
                    let stop = self.stop.clone();
                    std::thread::spawn(move || {
                        handle_conn(stream, router, stop);
                    });
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(_) => break,
            }
        }
    }

    pub fn stop_handle(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }
}

fn handle_conn(
    stream: TcpStream,
    router: Arc<Router>,
    stop: Arc<AtomicBool>,
) {
    stream.set_nodelay(true).ok();
    let reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    // Writer thread: serializes responses from all in-flight requests.
    let (out_tx, out_rx) = mpsc::channel::<Response>();
    let mut wstream = stream;
    let writer = std::thread::spawn(move || {
        for resp in out_rx {
            let mut line = resp.to_line();
            line.push('\n');
            if wstream.write_all(line.as_bytes()).is_err() {
                break;
            }
        }
    });

    for line in reader.lines() {
        if stop.load(Ordering::Acquire) {
            break;
        }
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        match Request::parse_line(&line) {
            Ok(req) => {
                let id = req.id;
                match router.submit(req) {
                    Ok(rx) => {
                        // Forward the response asynchronously.
                        let out_tx = out_tx.clone();
                        std::thread::spawn(move || {
                            if let Ok(resp) = rx.recv() {
                                let _ = out_tx.send(resp);
                            }
                        });
                    }
                    Err(e) => {
                        let _ = out_tx.send(Response {
                            id,
                            result: Err(format!("backpressure: {e:?}")),
                            latency_us: 0.0,
                        });
                    }
                }
            }
            Err(e) => {
                let _ = out_tx.send(Response {
                    id: 0,
                    result: Err(format!("bad request: {e}")),
                    latency_us: 0.0,
                });
            }
        }
    }
    drop(out_tx);
    let _ = writer.join();
}
