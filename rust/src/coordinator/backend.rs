//! Inference backends the router can dispatch to.
//!
//! Every dataset exposes up to five single-output variants — the exact
//! comparison matrix of the paper's evaluation — plus the multiclass
//! lane (§4.6):
//!
//! | kind      | engine                         | paper column |
//! |-----------|--------------------------------|--------------|
//! | `rs`      | RaceSketch (pure rust hot path)| RS           |
//! | `nn`      | rust dense MLP                 | NN           |
//! | `kernel`  | rust exact weighted KDE        | Kernel       |
//! | `nn-pjrt` | PJRT executable of nn.hlo.txt  | NN (XLA)     |
//! | `kernel-pjrt` | PJRT of kernel.hlo.txt (L1 Pallas) | Kernel (XLA) |
//! | `mc`      | FusedMultiSketch (class-interleaved) | — (§4.6) |
//! | `sh`      | ShardedSketch (scatter/gather shards)| — (scale-out) |
//!
//! A drained `DynamicBatcher` batch executes as ONE engine call: the
//! sketch lane runs the batch-major kernel
//! (`RaceSketch::query_batch_with`), the multiclass lane runs the fused
//! class-interleaved kernel (one CSC hash walk and one contiguous
//! gather serve the whole batch AND all classes; responses carry the
//! argmax class index, plus the full score vector when the request set
//! `"scores": true` — see [`BatchOutput`]).
//!
//! ## Parallel fan-out: the persistent sharded pool
//!
//! Batches of at least [`PAR_MIN_BATCH`] rows are split into contiguous
//! *row* shards and executed on [`WorkerPool::shared`] — long-lived
//! worker threads with per-worker channel-fed queues and per-worker
//! scratch (see [`super::pool`]).  Nothing on the hot path spawns a
//! thread: the engines stage each shard's rows into an owned buffer,
//! `Arc`-share the model, and block until all shards report back.
//! Below the threshold the lane thread runs the one batched kernel
//! call itself with the engine's own scratch.  Results are
//! bit-identical to the per-row scalar path regardless of batch size or
//! shard count, so batching and pooling are purely throughput knobs.
//!
//! ## The `sh` lane: model sharding, not batch sharding
//!
//! [`ShardedEngine`] splits along the OTHER axis: the sketch's L
//! repetitions are partitioned into whole MoM groups per
//! [`crate::shard::SketchShard`], every drained batch fans out as
//! exactly one shard-kernel submission per shard (every batch size,
//! B = 1 included — the contract the integration tests lock), and the
//! partial group means are merged estimator-exactly on the lane
//! thread.  Batch sharding multiplies throughput when B is large;
//! model sharding cuts single-batch latency by streaming N disjoint
//! counter slices in parallel, and is the unit the multi-process /
//! multi-host roadmap items build on.

use super::pool::{WorkerPool, WorkerScratch};
use crate::kernel::KernelModel;
use crate::metrics::slo::UpdateSlo;
use crate::nn::{Mlp, MlpScratch};
use crate::runtime::Executable;
use crate::shard::{self, MergeScratch, ShardedSketch};
use crate::sketch::epoch::{CounterPlane, MAX_PENDING};
use crate::sketch::{BatchScratch, FusedMultiSketch, FusedScratch,
                    QuantScratch, QuantSketch, RaceSketch, SrpScratch,
                    SrpSketch};
use std::sync::Arc;

/// Which backend variant a request targets.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BackendKind {
    Sketch,
    NnRust,
    KernelRust,
    NnPjrt,
    KernelPjrt,
    Multiclass,
    Sharded,
}

impl BackendKind {
    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Sketch => "rs",
            BackendKind::NnRust => "nn",
            BackendKind::KernelRust => "kernel",
            BackendKind::NnPjrt => "nn-pjrt",
            BackendKind::KernelPjrt => "kernel-pjrt",
            BackendKind::Multiclass => "mc",
            BackendKind::Sharded => "sh",
        }
    }

    pub fn parse(s: &str) -> Option<BackendKind> {
        Some(match s {
            "rs" | "sketch" => BackendKind::Sketch,
            "nn" | "nn-rust" => BackendKind::NnRust,
            "kernel" | "kernel-rust" => BackendKind::KernelRust,
            "nn-pjrt" => BackendKind::NnPjrt,
            "kernel-pjrt" => BackendKind::KernelPjrt,
            "mc" | "multiclass" => BackendKind::Multiclass,
            "sh" | "sharded" => BackendKind::Sharded,
            _ => return None,
        })
    }

    pub const ALL: [BackendKind; 7] = [
        BackendKind::Sketch,
        BackendKind::NnRust,
        BackendKind::KernelRust,
        BackendKind::NnPjrt,
        BackendKind::KernelPjrt,
        BackendKind::Multiclass,
        BackendKind::Sharded,
    ];
}

/// Flat per-class scores for one engine call: row i's vector is
/// `flat[i * n_classes..(i + 1) * n_classes]`.  Kept flat so the batch
/// crosses the engine boundary as ONE allocation; the router slices
/// out (and only then allocates) the rows whose requests asked.
pub struct ScoreMatrix {
    pub n_classes: usize,
    pub flat: Vec<f32>,
}

impl ScoreMatrix {
    /// Row `i`'s per-class scores, if in range.
    pub fn row(&self, i: usize) -> Option<&[f32]> {
        self.flat.get(i * self.n_classes..(i + 1) * self.n_classes)
    }
}

/// One engine call's output: per-row scalar values (estimate or argmax
/// class index), plus the score matrix when the call asked for it and
/// the engine is multiclass.
pub struct BatchOutput {
    pub values: Vec<f32>,
    /// `None` for single-output engines or when not requested.
    pub scores: Option<ScoreMatrix>,
}

/// One live mutation: add (`alpha > 0`) or delete (`alpha < 0`) weight
/// `|alpha|` of feature point `x` for `class` (0 for single-output
/// sketches).  `x` lives in the PROJECTED space — `p`-dimensional, the
/// same space the sketch's support points occupy — because an update
/// extends the kernel expansion, it does not query it.
#[derive(Clone, Debug, PartialEq)]
pub struct UpdateRow {
    pub x: Vec<f32>,
    pub alpha: f32,
    pub class: usize,
}

/// What a mutable engine acknowledges after applying an update batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct UpdateAck {
    /// Live plane epoch after the batch (bumped iff a publish ran).
    pub epoch: u64,
    /// Deltas still buffered in the shadow plane — 0 right after a
    /// publish, and never more than
    /// [`crate::sketch::epoch::MAX_PENDING`] (the staleness bound).
    pub pending: u64,
}

/// A batch-evaluating engine.  Instances are created *and used* on their
/// lane's worker thread (see `Router::add_lane`), so no `Send` bound —
/// which is what lets non-`Send` PJRT executables serve traffic.  CPU
/// engines fan large batches out to the shared [`WorkerPool`] (jobs own
/// their shard inputs, so only the job closures need `Send`).
pub trait Engine {
    /// Expected input dimensionality.
    fn dim(&self) -> usize;
    /// Evaluate a batch of feature rows into scalars.
    fn eval_batch(&mut self, rows: &[Vec<f32>]) -> anyhow::Result<Vec<f32>>;
    /// Evaluate a batch, optionally materializing per-class score
    /// vectors.  The default forwards to [`Engine::eval_batch`] with no
    /// scores; multiclass engines (`mc`, `sh`) override it.  Still ONE
    /// engine call per drained batch — `want_scores` is a flag, not a
    /// second pass.
    fn eval_batch_ex(
        &mut self,
        rows: &[Vec<f32>],
        want_scores: bool,
    ) -> anyhow::Result<BatchOutput> {
        let _ = want_scores;
        Ok(BatchOutput { values: self.eval_batch(rows)?, scores: None })
    }
    /// `(p, n_classes)` an [`UpdateRow`] must satisfy, or `None` when
    /// the backend is immutable (frozen artifacts: `nn`, `kernel`, the
    /// PJRT lanes).
    fn update_shape(&self) -> Option<(usize, usize)> {
        None
    }
    /// Apply a batch of live mutations against the engine's counter
    /// plane(s).  `publish` forces the deltas visible before returning;
    /// otherwise they surface at the next publish — which is never
    /// farther away than [`MAX_PENDING`] buffered deltas or the next
    /// query eval (every eval publishes first for read-your-writes; see
    /// [`crate::sketch::epoch`]).  The default rejects the batch: only
    /// sketch-backed lanes are mutable.
    fn apply_updates(
        &mut self,
        ups: &[UpdateRow],
        publish: bool,
    ) -> anyhow::Result<UpdateAck> {
        let _ = (ups, publish);
        anyhow::bail!("this backend does not support updates")
    }
    /// Live update/staleness counters, when the backend has a plane.
    fn plane_stats(&self) -> Option<Arc<UpdateSlo>> {
        None
    }
}

/// Fan a batch out across the pool only when it is at least this large
/// (below this, one batched kernel call on the lane thread wins).
const PAR_MIN_BATCH: usize = 64;
/// Minimum rows per pool shard (handoff overhead floor).
const PAR_MIN_CHUNK: usize = 16;

/// Shard count for a batch of `n` rows on `pool`: enough shards to keep
/// each above `PAR_MIN_CHUNK` rows, never more than the pool's workers.
fn shard_count(pool: &WorkerPool, n: usize) -> usize {
    pool.workers().min(n / PAR_MIN_CHUNK).max(1)
}

/// Flatten `rows` (validated earlier) into contiguous per-shard buffers
/// of `chunk_rows` rows each.
fn shard_rows(rows: &[Vec<f32>], chunk_rows: usize, d: usize)
    -> Vec<Vec<f32>> {
    rows.chunks(chunk_rows)
        .map(|chunk| {
            let mut flat = Vec::with_capacity(chunk.len() * d);
            for r in chunk {
                flat.extend_from_slice(r);
            }
            flat
        })
        .collect()
}

/// RS hot path: batch-major sketch kernel, pool fan-out for big batches.
///
/// Queries run against the live [`CounterPlane`] (seeded from the built
/// sketch's counters), so the lane serves streamed `update`s without
/// rebuilding — and answers stay bit-identical to a from-scratch build
/// holding the same points (the epoch-plane replay guarantee).
pub struct SketchEngine {
    pub sketch: Arc<RaceSketch>,
    /// Epoch-versioned live view of `sketch`'s counters (C = 1).
    plane: Arc<CounterPlane>,
    pool: Arc<WorkerPool>,
    flat: Vec<f32>,
    scratch: BatchScratch,
    /// Update-path hash scratch (codes + per-row columns).
    up_codes: Vec<i32>,
    up_cols: Vec<u32>,
}

impl SketchEngine {
    pub fn new(sketch: RaceSketch) -> Self {
        Self::with_pool(sketch, WorkerPool::shared())
    }

    pub fn with_pool(sketch: RaceSketch, pool: Arc<WorkerPool>) -> Self {
        let plane = Arc::new(sketch.plane());
        Self {
            sketch: Arc::new(sketch),
            plane,
            pool,
            flat: Vec::new(),
            scratch: BatchScratch::default(),
            up_codes: Vec::new(),
            up_cols: Vec::new(),
        }
    }
}

impl Engine for SketchEngine {
    fn dim(&self) -> usize {
        self.sketch.d
    }

    fn eval_batch(&mut self, rows: &[Vec<f32>]) -> anyhow::Result<Vec<f32>> {
        if rows.is_empty() {
            return Ok(Vec::new());
        }
        let d = self.sketch.d;
        for (i, r) in rows.iter().enumerate() {
            anyhow::ensure!(
                r.len() == d,
                "row {i} has dim {}, want {d}",
                r.len()
            );
        }
        // Read-your-writes: surface any buffered updates before
        // answering (no-op when the plane is clean).
        self.plane.publish();
        let n = rows.len();
        let shards = shard_count(&self.pool, n);
        if n < PAR_MIN_BATCH || shards < 2 {
            // One batched kernel call on the lane thread, scratch reused.
            self.flat.clear();
            self.flat.reserve(n * d);
            for r in rows {
                self.flat.extend_from_slice(r);
            }
            let pin = self.plane.pin();
            return Ok(self
                .sketch
                .query_batch_on(&pin.counters, pin.alpha_sums[0],
                                &self.flat, &mut self.scratch)
                .to_vec());
        }
        // Sharded fan-out through the persistent pool: each shard job
        // owns its rows and runs the batched kernel with the worker's
        // resident scratch.  Per-query results are independent and the
        // batched path is bit-identical to scalar, so the split cannot
        // change answers.
        let chunk_rows = (n + shards - 1) / shards;
        let jobs: Vec<_> = shard_rows(rows, chunk_rows, d)
            .into_iter()
            .map(|flat| {
                let sketch = self.sketch.clone();
                let plane = self.plane.clone();
                move |ws: &mut WorkerScratch| {
                    // Every job pins the same epoch: the lane thread —
                    // the plane's only writer — is blocked in run_jobs
                    // until all shards report back.
                    let pin = plane.pin();
                    sketch
                        .query_batch_on(&pin.counters, pin.alpha_sums[0],
                                        &flat, &mut ws.batch)
                        .to_vec()
                }
            })
            .collect();
        Ok(self.pool.run_jobs(jobs).concat())
    }

    fn update_shape(&self) -> Option<(usize, usize)> {
        Some((self.sketch.p, 1))
    }

    fn apply_updates(
        &mut self,
        ups: &[UpdateRow],
        publish: bool,
    ) -> anyhow::Result<UpdateAck> {
        let p = self.sketch.p;
        // Validate the WHOLE batch before touching the plane: a bad row
        // rejects the batch without applying a prefix of it.
        for (i, u) in ups.iter().enumerate() {
            anyhow::ensure!(
                u.x.len() == p,
                "update {i} has dim {}, want {p}",
                u.x.len()
            );
            anyhow::ensure!(
                u.class == 0,
                "update {i} targets class {} of a single-output sketch",
                u.class
            );
            anyhow::ensure!(
                u.alpha.is_finite(),
                "update {i} has non-finite weight"
            );
        }
        for u in ups {
            self.sketch.delta_cols(&u.x, &mut self.up_codes,
                                   &mut self.up_cols);
            if self.plane.apply(&self.up_cols, 0, u.alpha) >= MAX_PENDING {
                // Bounded staleness: never let more than MAX_PENDING
                // deltas ride in the shadow buffer.
                self.plane.publish();
            }
        }
        if publish {
            self.plane.publish();
        }
        let st = self.plane.stats();
        Ok(UpdateAck {
            epoch: self.plane.epoch(),
            pending: st
                .pending
                // ORDERING: Relaxed — advisory stat mirror maintained
                // by the plane under its writer mutex (see UpdateSlo).
                .load(std::sync::atomic::Ordering::Relaxed),
        })
    }

    fn plane_stats(&self) -> Option<Arc<UpdateSlo>> {
        Some(self.plane.stats())
    }
}

/// Rust dense MLP.
pub struct MlpEngine {
    pub mlp: Mlp,
    scratch: MlpScratch,
}

impl MlpEngine {
    pub fn new(mlp: Mlp) -> Self {
        Self { mlp, scratch: MlpScratch::default() }
    }
}

impl Engine for MlpEngine {
    fn dim(&self) -> usize {
        self.mlp.input_dim()
    }

    fn eval_batch(&mut self, rows: &[Vec<f32>]) -> anyhow::Result<Vec<f32>> {
        Ok(rows
            .iter()
            .map(|r| self.mlp.forward_with(r, &mut self.scratch))
            .collect())
    }
}

/// Rust exact weighted KDE (O(M·p) per row — the heaviest rust engine,
/// so large batches fan out across the pool).
pub struct KernelEngine {
    pub model: Arc<KernelModel>,
    pool: Arc<WorkerPool>,
}

impl KernelEngine {
    pub fn new(model: KernelModel) -> Self {
        Self::with_pool(model, WorkerPool::shared())
    }

    pub fn with_pool(model: KernelModel, pool: Arc<WorkerPool>) -> Self {
        Self { model: Arc::new(model), pool }
    }
}

impl Engine for KernelEngine {
    fn dim(&self) -> usize {
        self.model.params.d
    }

    fn eval_batch(&mut self, rows: &[Vec<f32>]) -> anyhow::Result<Vec<f32>> {
        let n = rows.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let d = self.model.params.d;
        for (i, r) in rows.iter().enumerate() {
            anyhow::ensure!(
                r.len() == d,
                "row {i} has dim {}, want {d}",
                r.len()
            );
        }
        let shards = shard_count(&self.pool, n);
        if n < PAR_MIN_BATCH || shards < 2 {
            return Ok(self.model.predict_batch(rows));
        }
        let chunk_rows = (n + shards - 1) / shards;
        let jobs: Vec<_> = shard_rows(rows, chunk_rows, d)
            .into_iter()
            .map(|flat| {
                let model = self.model.clone();
                move |_ws: &mut WorkerScratch| {
                    flat.chunks_exact(d)
                        .map(|r| model.predict(r))
                        .collect::<Vec<_>>()
                }
            })
            .collect();
        Ok(self.pool.run_jobs(jobs).concat())
    }
}

/// Per-row argmax over a flat `(B, C)` score matrix — the shared tail
/// of the `mc` and `sh` lanes.  Tie-breaking is the sketch-wide
/// `crate::sketch::argmax`, so wire answers match every in-process
/// predict path.
fn argmax_values(scores: &[f32], n_classes: usize) -> Vec<f32> {
    scores
        .chunks_exact(n_classes)
        .map(|row| crate::sketch::argmax(row) as f32)
        .collect()
}

/// Merged `(B, C)` shard scores → wire-facing output: the tail every
/// `sh`-lane variant (local pool and remote plane) shares, so both
/// answer IDENTICALLY.  Single-output (RSSK-shaped) sketches answer
/// the estimate; multiclass sketches (a C = 1 RSFM included) answer
/// the argmax index plus optional scores — exactly what the `mc` lane
/// answers for the same model.
fn sharded_batch_output(
    head: &crate::shard::ShardHead,
    scores: &[f32],
    want_scores: bool,
) -> BatchOutput {
    if !head.multiclass {
        return BatchOutput { values: scores.to_vec(), scores: None };
    }
    let c_n = head.n_classes;
    BatchOutput {
        values: argmax_values(scores, c_n),
        scores: want_scores.then(|| ScoreMatrix {
            n_classes: c_n,
            flat: scores.to_vec(),
        }),
    }
}

/// The `sh` lanes' empty-batch answer (same score-matrix presence rule
/// as the non-empty path).
fn sharded_empty_output(
    head: &crate::shard::ShardHead,
    want_scores: bool,
) -> BatchOutput {
    BatchOutput {
        values: Vec::new(),
        scores: (want_scores && head.multiclass).then(|| ScoreMatrix {
            n_classes: head.n_classes,
            flat: Vec::new(),
        }),
    }
}

/// Shared `sh`-lane batch prologue: per-row dim validation, flatten,
/// and stage-1 projection into the transposed `(p, B)` layout — ONE
/// copy, because the local and remote lanes' bit-for-bit identity
/// depends on their shard kernels receiving identical inputs; a
/// prologue edit that reached only one lane would silently break the
/// contract the property tests lock.
fn project_sharded_batch(
    head: &crate::shard::ShardHead,
    rows: &[Vec<f32>],
    flat: &mut Vec<f32>,
    proj_row: &mut Vec<f32>,
    proj_t: &mut Vec<f32>,
) -> anyhow::Result<()> {
    let d = head.d;
    for (i, r) in rows.iter().enumerate() {
        anyhow::ensure!(
            r.len() == d,
            "row {i} has dim {}, want {d}",
            r.len()
        );
    }
    flat.clear();
    flat.reserve(rows.len() * d);
    for r in rows {
        flat.extend_from_slice(r);
    }
    shard::project_batch_t(
        &head.a,
        d,
        head.p,
        flat,
        rows.len(),
        proj_row,
        proj_t,
    );
    Ok(())
}

/// Multiclass lane: the fused class-interleaved sketch.  A drained batch
/// executes as ONE fused kernel call (one hash pass, one contiguous
/// gather for all C classes); responses carry the argmax class index as
/// an f32, plus the per-class score vector when requested.
pub struct MulticlassEngine {
    pub fused: Arc<FusedMultiSketch>,
    /// Epoch-versioned live view of the interleaved counters + per-class
    /// alpha sums — per-class `update`s land here.
    plane: Arc<CounterPlane>,
    pool: Arc<WorkerPool>,
    flat: Vec<f32>,
    scratch: FusedScratch,
    up_codes: Vec<i32>,
    up_cols: Vec<u32>,
}

impl MulticlassEngine {
    pub fn new(fused: FusedMultiSketch) -> Self {
        Self::with_pool(fused, WorkerPool::shared())
    }

    pub fn with_pool(fused: FusedMultiSketch, pool: Arc<WorkerPool>)
        -> Self {
        let plane = Arc::new(fused.plane());
        Self {
            fused: Arc::new(fused),
            plane,
            pool,
            flat: Vec::new(),
            scratch: FusedScratch::default(),
            up_codes: Vec::new(),
            up_cols: Vec::new(),
        }
    }
}

impl Engine for MulticlassEngine {
    fn dim(&self) -> usize {
        self.fused.d
    }

    fn eval_batch(&mut self, rows: &[Vec<f32>]) -> anyhow::Result<Vec<f32>> {
        Ok(self.eval_batch_ex(rows, false)?.values)
    }

    fn eval_batch_ex(
        &mut self,
        rows: &[Vec<f32>],
        want_scores: bool,
    ) -> anyhow::Result<BatchOutput> {
        let c_n = self.fused.n_classes();
        if rows.is_empty() {
            return Ok(BatchOutput {
                values: Vec::new(),
                scores: want_scores.then(|| ScoreMatrix {
                    n_classes: c_n,
                    flat: Vec::new(),
                }),
            });
        }
        let d = self.fused.d;
        for (i, r) in rows.iter().enumerate() {
            anyhow::ensure!(
                r.len() == d,
                "row {i} has dim {}, want {d}",
                r.len()
            );
        }
        // Read-your-writes before answering (no-op when clean).
        self.plane.publish();
        let n = rows.len();
        let shards = shard_count(&self.pool, n);
        if n < PAR_MIN_BATCH || shards < 2 {
            self.flat.clear();
            self.flat.reserve(n * d);
            for r in rows {
                self.flat.extend_from_slice(r);
            }
            let pin = self.plane.pin();
            let scores = self.fused.scores_batch_on(
                &pin.counters,
                &pin.alpha_sums,
                &self.flat,
                &mut self.scratch,
            );
            return Ok(BatchOutput {
                values: argmax_values(scores, c_n),
                scores: want_scores.then(|| ScoreMatrix {
                    n_classes: c_n,
                    flat: scores.to_vec(),
                }),
            });
        }
        let chunk_rows = (n + shards - 1) / shards;
        if !want_scores {
            // Argmax computed worker-side: one f32 per row crosses the
            // pool, not a (B, C) score matrix nobody asked for.
            let jobs: Vec<_> = shard_rows(rows, chunk_rows, d)
                .into_iter()
                .map(|flat| {
                    let fused = self.fused.clone();
                    let plane = self.plane.clone();
                    move |ws: &mut WorkerScratch| {
                        let pin = plane.pin();
                        let mut preds = Vec::new();
                        fused.predict_batch_on(&pin.counters,
                                               &pin.alpha_sums, &flat,
                                               &mut ws.fused, &mut preds);
                        preds.into_iter()
                            .map(|c| c as f32)
                            .collect::<Vec<_>>()
                    }
                })
                .collect();
            return Ok(BatchOutput {
                values: self.pool.run_jobs(jobs).concat(),
                scores: None,
            });
        }
        let jobs: Vec<_> = shard_rows(rows, chunk_rows, d)
            .into_iter()
            .map(|flat| {
                let fused = self.fused.clone();
                let plane = self.plane.clone();
                move |ws: &mut WorkerScratch| {
                    let pin = plane.pin();
                    fused
                        .scores_batch_on(&pin.counters, &pin.alpha_sums,
                                         &flat, &mut ws.fused)
                        .to_vec()
                }
            })
            .collect();
        let flat = self.pool.run_jobs(jobs).concat();
        Ok(BatchOutput {
            values: argmax_values(&flat, c_n),
            scores: Some(ScoreMatrix { n_classes: c_n, flat }),
        })
    }

    fn update_shape(&self) -> Option<(usize, usize)> {
        Some((self.fused.p, self.fused.n_classes()))
    }

    fn apply_updates(
        &mut self,
        ups: &[UpdateRow],
        publish: bool,
    ) -> anyhow::Result<UpdateAck> {
        let p = self.fused.p;
        let c_n = self.fused.n_classes();
        // Whole-batch validation first (no partial application).
        for (i, u) in ups.iter().enumerate() {
            anyhow::ensure!(
                u.x.len() == p,
                "update {i} has dim {}, want {p}",
                u.x.len()
            );
            anyhow::ensure!(
                u.class < c_n,
                "update {i} targets class {} of {c_n}",
                u.class
            );
            anyhow::ensure!(
                u.alpha.is_finite(),
                "update {i} has non-finite weight"
            );
        }
        for u in ups {
            self.fused.delta_cols(&u.x, &mut self.up_codes,
                                  &mut self.up_cols);
            if self.plane.apply(&self.up_cols, u.class, u.alpha)
                >= MAX_PENDING
            {
                self.plane.publish();
            }
        }
        if publish {
            self.plane.publish();
        }
        let st = self.plane.stats();
        Ok(UpdateAck {
            epoch: self.plane.epoch(),
            pending: st
                .pending
                // ORDERING: Relaxed — advisory stat mirror maintained
                // by the plane under its writer mutex (see UpdateSlo).
                .load(std::sync::atomic::Ordering::Relaxed),
        })
    }

    fn plane_stats(&self) -> Option<Arc<UpdateSlo>> {
        Some(self.plane.stats())
    }
}

/// A quantized counter plane serving the `rs` or `mc` wire kind: a
/// [`QuantSketch`] answers single-output estimates (RSQK shape) or
/// multiclass argmax + optional scores (RSQM shape) with 2–4× fewer
/// counter bytes moved per query.  Scores differ from the f32 lane by
/// at most [`QuantSketch::score_tolerance`] (the measured tolerance
/// contract).  Read-only: there is no f32 buffer to fold updates into,
/// so the default [`Engine::apply_updates`] bail and
/// `update_shape() == None` apply — a quantized lane rejects `update`
/// traffic instead of silently drifting from its tables.
pub struct QuantEngine {
    pub quant: Arc<QuantSketch>,
    pool: Arc<WorkerPool>,
    flat: Vec<f32>,
    scratch: QuantScratch,
}

impl QuantEngine {
    pub fn new(quant: QuantSketch) -> Self {
        Self::with_pool(quant, WorkerPool::shared())
    }

    pub fn with_pool(quant: QuantSketch, pool: Arc<WorkerPool>) -> Self {
        Self {
            quant: Arc::new(quant),
            pool,
            flat: Vec::new(),
            scratch: QuantScratch::default(),
        }
    }

    /// Shape `(B, C)` scores into the wire-facing output — the same
    /// rule as the f32 lanes: single-output planes answer the raw
    /// estimate, multiclass planes answer the argmax index plus the
    /// score matrix on request.
    fn shape_output(&self, scores: Vec<f32>, want_scores: bool)
        -> BatchOutput {
        if !self.quant.multiclass {
            return BatchOutput { values: scores, scores: None };
        }
        let c_n = self.quant.n_classes;
        BatchOutput {
            values: argmax_values(&scores, c_n),
            scores: want_scores.then(|| ScoreMatrix {
                n_classes: c_n,
                flat: scores,
            }),
        }
    }
}

impl Engine for QuantEngine {
    fn dim(&self) -> usize {
        self.quant.d
    }

    fn eval_batch(&mut self, rows: &[Vec<f32>]) -> anyhow::Result<Vec<f32>> {
        Ok(self.eval_batch_ex(rows, false)?.values)
    }

    fn eval_batch_ex(
        &mut self,
        rows: &[Vec<f32>],
        want_scores: bool,
    ) -> anyhow::Result<BatchOutput> {
        let c_n = self.quant.n_classes;
        if rows.is_empty() {
            return Ok(BatchOutput {
                values: Vec::new(),
                scores: (want_scores && self.quant.multiclass).then(
                    || ScoreMatrix { n_classes: c_n, flat: Vec::new() },
                ),
            });
        }
        let d = self.quant.d;
        for (i, r) in rows.iter().enumerate() {
            anyhow::ensure!(
                r.len() == d,
                "row {i} has dim {}, want {d}",
                r.len()
            );
        }
        let n = rows.len();
        let shards = shard_count(&self.pool, n);
        if n < PAR_MIN_BATCH || shards < 2 {
            self.flat.clear();
            self.flat.reserve(n * d);
            for r in rows {
                self.flat.extend_from_slice(r);
            }
            let scores = self
                .quant
                .scores_batch_with(&self.flat, &mut self.scratch)
                .to_vec();
            return Ok(self.shape_output(scores, want_scores));
        }
        // Pool fan-out, same shape as the f32 lanes: batch-sharded
        // jobs against the shared read-only plane, per-worker scratch.
        let chunk_rows = (n + shards - 1) / shards;
        if self.quant.multiclass && !want_scores {
            // Argmax computed worker-side: one f32 per row crosses
            // the pool, not a (B, C) score matrix nobody asked for.
            let jobs: Vec<_> = shard_rows(rows, chunk_rows, d)
                .into_iter()
                .map(|flat| {
                    let quant = self.quant.clone();
                    move |ws: &mut WorkerScratch| {
                        let mut preds = Vec::new();
                        quant.predict_batch_with(&flat, &mut ws.quant,
                                                 &mut preds);
                        preds.into_iter()
                            .map(|c| c as f32)
                            .collect::<Vec<_>>()
                    }
                })
                .collect();
            return Ok(BatchOutput {
                values: self.pool.run_jobs(jobs).concat(),
                scores: None,
            });
        }
        let jobs: Vec<_> = shard_rows(rows, chunk_rows, d)
            .into_iter()
            .map(|flat| {
                let quant = self.quant.clone();
                move |ws: &mut WorkerScratch| {
                    quant
                        .scores_batch_with(&flat, &mut ws.quant)
                        .to_vec()
                }
            })
            .collect();
        let scores = self.pool.run_jobs(jobs).concat();
        Ok(self.shape_output(scores, want_scores))
    }
}

/// The SRP-family lane: a `build-sketch --family srp` artifact (RSRP
/// on disk) served on the `rs` wire kind — clients address it exactly
/// like an L2 sketch lane and cannot tell the hash family from the
/// protocol.  Scalar path only (the batch-major and pool fan-out
/// machinery is L2-specific; an SRP batch kernel is future work), so a
/// drained batch runs a per-row `query_with` loop on the lane thread
/// with one resident scratch.  Read-only: SRP sketches have no epoch
/// plane yet, so the default [`Engine::apply_updates`] bail and
/// `update_shape() == None` apply — the lane refuses `update` traffic
/// instead of silently dropping it.
pub struct SrpEngine {
    pub sketch: Arc<SrpSketch>,
    scratch: SrpScratch,
}

impl SrpEngine {
    pub fn new(sketch: SrpSketch) -> Self {
        Self { sketch: Arc::new(sketch), scratch: SrpScratch::default() }
    }
}

impl Engine for SrpEngine {
    fn dim(&self) -> usize {
        self.sketch.d
    }

    fn eval_batch(&mut self, rows: &[Vec<f32>]) -> anyhow::Result<Vec<f32>> {
        let d = self.sketch.d;
        for (i, r) in rows.iter().enumerate() {
            anyhow::ensure!(
                r.len() == d,
                "row {i} has dim {}, want {d}",
                r.len()
            );
        }
        Ok(rows
            .iter()
            .map(|r| self.sketch.query_with(r, &mut self.scratch))
            .collect())
    }
}

/// The `sh` lane: a sketch partitioned into whole-MoM-group shards.
/// Every drained batch is projected ONCE on the lane thread, fanned out
/// as exactly one shard-kernel submission per shard through the
/// persistent pool (every batch size — model sharding cuts latency, so
/// there is no fan-out threshold), and merged estimator-exactly on the
/// lane thread.  Single-output sketches answer the estimate;
/// multiclass sketches answer the argmax index plus optional scores —
/// both bit-for-bit identical to the monolithic `rs` / `mc` lanes.
pub struct ShardedEngine {
    pub sharded: Arc<ShardedSketch>,
    /// One live plane per shard, kept in LOCKSTEP: every update's
    /// per-shard delta lands in every plane under one apply sequence
    /// and publishes flip all planes together, so a batch that pins
    /// after a publish sees ONE consistent model version across
    /// shards.  Each plane carries the FULL per-class alpha sums (the
    /// merge debiases once, globally), so `planes[0]`'s pinned
    /// `alpha_sums` are the model's.
    planes: Vec<Arc<CounterPlane>>,
    pool: Arc<WorkerPool>,
    flat: Vec<f32>,
    proj_row: Vec<f32>,
    /// Stage-1 output, `Arc`-shared with the shard jobs and reclaimed
    /// for reuse after the `run_jobs` barrier (refcount is back to 1
    /// once every job has run — the allocation-free steady state the
    /// other engines keep with their plain scratch fields).
    proj_t: Arc<Vec<f32>>,
    merge: MergeScratch,
    scores: Vec<f32>,
    up_codes: Vec<i32>,
    up_cols: Vec<u32>,
}

impl ShardedEngine {
    pub fn new(sharded: ShardedSketch) -> Self {
        Self::with_pool(sharded, WorkerPool::shared())
    }

    pub fn with_pool(sharded: ShardedSketch, pool: Arc<WorkerPool>)
        -> Self {
        let sharded = Arc::new(sharded);
        let planes = sharded
            .shards
            .iter()
            .map(|sh| Arc::new(sh.plane(&sharded.head.alpha_sums)))
            .collect();
        Self {
            sharded,
            planes,
            pool,
            flat: Vec::new(),
            proj_row: Vec::new(),
            proj_t: Arc::new(Vec::new()),
            merge: MergeScratch::default(),
            scores: Vec::new(),
            up_codes: Vec::new(),
            up_cols: Vec::new(),
        }
    }
}

impl Engine for ShardedEngine {
    fn dim(&self) -> usize {
        self.sharded.head.d
    }

    fn eval_batch(&mut self, rows: &[Vec<f32>]) -> anyhow::Result<Vec<f32>> {
        Ok(self.eval_batch_ex(rows, false)?.values)
    }

    fn eval_batch_ex(
        &mut self,
        rows: &[Vec<f32>],
        want_scores: bool,
    ) -> anyhow::Result<BatchOutput> {
        let head = &self.sharded.head;
        if rows.is_empty() {
            return Ok(sharded_empty_output(head, want_scores));
        }
        // Read-your-writes: publish every shard plane (lockstep — all
        // are clean or all carry the same pending sequence).
        for pl in &self.planes {
            pl.publish();
        }
        let n = rows.len();
        // Reclaim the shared stage-1 buffer from the previous batch
        // (its jobs all finished before run_jobs returned, so the
        // refcount is 1; if a worker is somehow still dropping its
        // clone, fall back to a fresh allocation rather than block).
        if Arc::get_mut(&mut self.proj_t).is_none() {
            self.proj_t = Arc::new(Vec::new());
        }
        // Stage 1 once, on the lane thread (Arc-shared with the shard
        // jobs — the d·p·B work is NOT duplicated per shard).
        project_sharded_batch(
            head,
            rows,
            &mut self.flat,
            &mut self.proj_row,
            Arc::get_mut(&mut self.proj_t).expect("uniquely owned"),
        )?;
        let proj_t = self.proj_t.clone();
        // Exactly ONE shard-kernel submission per shard per drained
        // batch (the integration-tested contract): each job hashes its
        // own repetitions against the shared projections and returns
        // complete group means for its groups.
        let jobs: Vec<_> = self
            .sharded
            .shards
            .iter()
            .zip(self.planes.iter())
            .map(|(sh, pl)| {
                let sh = sh.clone();
                let pl = pl.clone();
                let proj_t = proj_t.clone();
                move |ws: &mut WorkerScratch| {
                    // Same epoch in every job: the lane thread — the
                    // planes' only writer — is blocked in run_jobs.
                    let pin = pl.pin();
                    let mut out = Vec::new();
                    sh.partial_means_batch_on(&pin.counters, &proj_t, n,
                                              &mut ws.shard, &mut out);
                    out
                }
            })
            .collect();
        let partials = self.pool.run_jobs(jobs);
        // Estimator-exact merge on the submitting (lane) thread, with
        // the debias terms read from the same plane generation the
        // shard kernels pinned.  The merge validates shapes;
        // pool-computed partials always pass.
        let pin0 = self.planes[0].pin();
        shard::merge_scores_into_with(
            head,
            &self.sharded.plan,
            &partials,
            n,
            &pin0.alpha_sums,
            &mut self.merge,
            &mut self.scores,
        )
        .map_err(|e| anyhow::anyhow!("shard merge: {e}"))?;
        drop(pin0);
        Ok(sharded_batch_output(head, &self.scores, want_scores))
    }

    fn update_shape(&self) -> Option<(usize, usize)> {
        if self.sharded.is_quantized() {
            // Quantized shard sets are read-only (no f32 buffer to
            // fold deltas into) — advertise immutability.
            return None;
        }
        Some((self.sharded.head.p, self.sharded.head.n_classes))
    }

    fn apply_updates(
        &mut self,
        ups: &[UpdateRow],
        publish: bool,
    ) -> anyhow::Result<UpdateAck> {
        anyhow::ensure!(
            !self.sharded.is_quantized(),
            "this sharded lane serves a quantized (read-only) plane; \
             updates require the f32 shard set"
        );
        let p = self.sharded.head.p;
        let c_n = self.sharded.head.n_classes;
        // Whole-batch validation first (no partial application).
        for (i, u) in ups.iter().enumerate() {
            anyhow::ensure!(
                u.x.len() == p,
                "update {i} has dim {}, want {p}",
                u.x.len()
            );
            anyhow::ensure!(
                u.class < c_n,
                "update {i} targets class {} of {c_n}",
                u.class
            );
            anyhow::ensure!(
                u.alpha.is_finite(),
                "update {i} has non-finite weight"
            );
        }
        for u in ups {
            // One delta per shard, every plane under the same sequence
            // number — the planes stay an exact carve of the monolithic
            // plane (global row salt in `delta_cols`).
            let mut pending = 0;
            for (sh, pl) in
                self.sharded.shards.iter().zip(self.planes.iter())
            {
                sh.delta_cols(&u.x, &mut self.up_codes,
                              &mut self.up_cols);
                pending = pl.apply(&self.up_cols, u.class, u.alpha);
            }
            if pending >= MAX_PENDING {
                for pl in &self.planes {
                    pl.publish();
                }
            }
        }
        if publish {
            for pl in &self.planes {
                pl.publish();
            }
        }
        // Lockstep means every plane reports identical counters; shard
        // 0 speaks for the set.
        let st = self.planes[0].stats();
        Ok(UpdateAck {
            epoch: self.planes[0].epoch(),
            pending: st
                .pending
                // ORDERING: Relaxed — advisory stat mirror maintained
                // by the plane under its writer mutex (see UpdateSlo).
                .load(std::sync::atomic::Ordering::Relaxed),
        })
    }

    fn plane_stats(&self) -> Option<Arc<UpdateSlo>> {
        Some(self.planes[0].stats())
    }
}

/// The remote `sh` lane: shard kernels living in OTHER processes (or
/// hosts), reached through `shard::remote::RemoteShardSet`.  Identical
/// execution shape to [`ShardedEngine`] with the pool swapped for the
/// wire: project ONCE on the lane thread, scatter one request per
/// persistent shard connection (pipelined, nonblocking, zero spawns —
/// the lane thread drives the sockets itself), gather the complete
/// group means, and run the untouched `ShardMerge` — so the remote
/// lane is bit-for-bit identical to the local `sh` lane and the
/// unsharded scalar path.  A failing shard fails the batch with an
/// error NAMING it (the router turns that into per-request error
/// responses — never silence, never a partial merge), and the next
/// batch reconnects.
#[cfg(target_os = "linux")]
pub struct RemoteShardedEngine {
    set: crate::shard::RemoteShardSet,
    flat: Vec<f32>,
    proj_row: Vec<f32>,
    proj_t: Vec<f32>,
    partials: Vec<Vec<f32>>,
    merge: MergeScratch,
    scores: Vec<f32>,
}

#[cfg(target_os = "linux")]
impl RemoteShardedEngine {
    /// Connect + handshake-validate the whole set (addresses in
    /// shard-index order).  Fails fast if any shard is down or serves
    /// the wrong sketch — a lane must not come up half-exact.
    pub fn connect(
        addrs: Vec<String>,
        timeout: std::time::Duration,
    ) -> anyhow::Result<Self> {
        Ok(Self::new(crate::shard::RemoteShardSet::connect(
            addrs, timeout,
        )?))
    }

    /// Like [`Self::connect`] with replica groups: `groups[s]` lists
    /// every replica address of shard `s`.  Every replica of every
    /// shard is dialed and handshake-validated up front (a lane must
    /// not come up half-exact); afterwards the set survives replica
    /// deaths via hedging, in-batch failover, and backed-off
    /// reintegration (see `shard::remote`).
    pub fn connect_replicated(
        groups: Vec<Vec<String>>,
        opts: crate::shard::RemoteOptions,
    ) -> anyhow::Result<Self> {
        Ok(Self::new(
            crate::shard::RemoteShardSet::connect_replicated(
                groups, opts,
            )?,
        ))
    }

    pub fn new(set: crate::shard::RemoteShardSet) -> Self {
        Self {
            set,
            flat: Vec::new(),
            proj_row: Vec::new(),
            proj_t: Vec::new(),
            partials: Vec::new(),
            merge: MergeScratch::default(),
            scores: Vec::new(),
        }
    }

    pub fn head(&self) -> &crate::shard::ShardHead {
        self.set.head()
    }

    pub fn n_shards(&self) -> usize {
        self.set.n_shards()
    }

    /// The set's live replication/SLO counters — grab the `Arc` before
    /// moving the engine into its lane, then register it with
    /// `Router::register_shard_stats` so the `stats` verb serves it.
    pub fn stats(&self)
        -> std::sync::Arc<crate::metrics::slo::RemoteShardStats> {
        self.set.stats()
    }
}

#[cfg(target_os = "linux")]
impl Engine for RemoteShardedEngine {
    fn dim(&self) -> usize {
        self.set.head().d
    }

    fn eval_batch(&mut self, rows: &[Vec<f32>]) -> anyhow::Result<Vec<f32>> {
        Ok(self.eval_batch_ex(rows, false)?.values)
    }

    fn eval_batch_ex(
        &mut self,
        rows: &[Vec<f32>],
        want_scores: bool,
    ) -> anyhow::Result<BatchOutput> {
        if rows.is_empty() {
            return Ok(sharded_empty_output(self.set.head(),
                                           want_scores));
        }
        let n = rows.len();
        // The SAME stage-1 prologue as the local lane (shared helper),
        // so the remote shards receive bit-identical inputs.
        project_sharded_batch(
            self.set.head(),
            rows,
            &mut self.flat,
            &mut self.proj_row,
            &mut self.proj_t,
        )?;
        // Scatter/gather over the persistent connections (one request
        // per shard, no spawns), then the untouched exact merge.
        self.set
            .gather_means(&self.proj_t, n, &mut self.partials)?;
        shard::merge_scores_into(
            self.set.head(),
            self.set.plan(),
            &self.partials,
            n,
            &mut self.merge,
            &mut self.scores,
        )
        .map_err(|e| {
            anyhow::anyhow!("remote shard merge rejected the gather: {e}")
        })?;
        Ok(sharded_batch_output(self.set.head(), &self.scores,
                                want_scores))
    }

    fn update_shape(&self) -> Option<(usize, usize)> {
        let h = self.set.head();
        Some((h.p, h.n_classes))
    }

    fn apply_updates(
        &mut self,
        ups: &[UpdateRow],
        publish: bool,
    ) -> anyhow::Result<UpdateAck> {
        let (p, c_n) = {
            let h = self.set.head();
            (h.p, h.n_classes)
        };
        for (i, u) in ups.iter().enumerate() {
            anyhow::ensure!(
                u.x.len() == p,
                "update {i} has dim {}, want {p}",
                u.x.len()
            );
            anyhow::ensure!(
                u.class < c_n,
                "update {i} targets class {} of {c_n}",
                u.class
            );
            anyhow::ensure!(
                u.alpha.is_finite(),
                "update {i} has non-finite weight"
            );
        }
        // Each row is broadcast to every replica of every shard (the
        // set mirrors the per-class alpha fold locally so the merge's
        // debias tracks the remote counters — see
        // `RemoteShardSet::broadcast_update`).  Shard servers publish
        // before answering means, so queries after these acks can never
        // observe a pre-update snapshot.
        let slo = self.set.update_slo();
        let mut ack = UpdateAck {
            // ORDERING: Relaxed on both — advisory stat mirrors; the
            // authoritative epoch traveled back in each shard ack.
            epoch: slo.epoch.load(std::sync::atomic::Ordering::Relaxed),
            pending: slo
                .pending
                // ORDERING: see above
                .load(std::sync::atomic::Ordering::Relaxed),
        };
        for (i, u) in ups.iter().enumerate() {
            let (epoch, pending) = self.set.broadcast_update(
                &u.x,
                u.alpha,
                u.class,
                publish && i + 1 == ups.len(),
            )?;
            ack = UpdateAck { epoch, pending };
        }
        Ok(ack)
    }

    fn plane_stats(&self) -> Option<Arc<UpdateSlo>> {
        Some(self.set.update_slo())
    }
}

/// PJRT executable (AOT artifact).
pub struct PjrtEngine {
    pub exe: Executable,
}

impl Engine for PjrtEngine {
    fn dim(&self) -> usize {
        self.exe.dim
    }

    fn eval_batch(&mut self, rows: &[Vec<f32>]) -> anyhow::Result<Vec<f32>> {
        let mut out = Vec::with_capacity(rows.len());
        for chunk in rows.chunks(self.exe.batch) {
            let refs: Vec<&[f32]> =
                chunk.iter().map(|r| r.as_slice()).collect();
            out.extend(self.exe.run_batch(&refs)?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::KernelParams;
    use crate::sketch::{
        MultiSketch, QueryScratch, SketchConfig,
    };
    use crate::util::rng::SplitMix64;

    #[test]
    fn backend_kind_roundtrip() {
        for k in BackendKind::ALL {
            assert_eq!(BackendKind::parse(k.name()), Some(k));
        }
        assert_eq!(BackendKind::parse("multiclass"),
                   Some(BackendKind::Multiclass));
        assert_eq!(BackendKind::parse("bogus"), None);
    }

    fn random_kp(seed: u64, d: usize, p: usize, m: usize) -> KernelParams {
        let mut rng = SplitMix64::new(seed);
        KernelParams {
            d,
            p,
            m,
            a: (0..d * p).map(|_| rng.next_gaussian() as f32 * 0.5).collect(),
            x: (0..m * p).map(|_| rng.next_gaussian() as f32).collect(),
            alpha: (0..m).map(|_| 0.5 + rng.next_f32()).collect(),
            width: 2.0,
            lsh_seed: rng.next_u64(),
            k_per_row: 2,
            default_rows: 64,
            default_cols: 16,
        }
    }

    fn random_rows(seed: u64, n: usize, d: usize) -> Vec<Vec<f32>> {
        let mut rng = SplitMix64::new(seed);
        (0..n)
            .map(|_| (0..d).map(|_| rng.next_gaussian() as f32).collect())
            .collect()
    }

    #[test]
    fn sketch_engine_matches_scalar_for_all_batch_shapes() {
        // Covers the single-call path (< PAR_MIN_BATCH), the pool
        // fan-out path, and ragged final shards in both.
        let kp = random_kp(3, 7, 4, 30);
        let sketch = crate::sketch::RaceSketch::build(
            &kp,
            &SketchConfig::default(),
        );
        let pool = Arc::new(WorkerPool::new(4));
        let mut engine = SketchEngine::with_pool(sketch.clone(), pool);
        let mut s = QueryScratch::default();
        for &n in &[0usize, 1, 7, 63, 64, 67, 130, 257] {
            let rows = random_rows(100 + n as u64, n, 7);
            let got = engine.eval_batch(&rows).unwrap();
            assert_eq!(got.len(), n);
            for (i, r) in rows.iter().enumerate() {
                let want = sketch.query_with(r, &mut s);
                assert_eq!(got[i].to_bits(), want.to_bits(), "n={n} row {i}");
            }
        }
    }

    #[test]
    fn sketch_engine_rejects_bad_dim_rows() {
        let kp = random_kp(4, 5, 5, 10);
        let mut engine = SketchEngine::new(crate::sketch::RaceSketch::build(
            &kp,
            &SketchConfig::default(),
        ));
        assert!(engine.eval_batch(&[vec![0.0; 4]]).is_err());
    }

    #[test]
    fn kernel_engine_matches_scalar_across_par_threshold() {
        let kp = random_kp(5, 6, 3, 20);
        let reference = KernelModel::new(kp.clone());
        let pool = Arc::new(WorkerPool::new(4));
        let mut engine =
            KernelEngine::with_pool(KernelModel::new(kp), pool);
        for &n in &[1usize, 65, 130] {
            let rows = random_rows(200 + n as u64, n, 6);
            let got = engine.eval_batch(&rows).unwrap();
            for (i, r) in rows.iter().enumerate() {
                assert_eq!(
                    got[i].to_bits(),
                    reference.predict(r).to_bits(),
                    "n={n} row {i}"
                );
            }
        }
    }

    #[test]
    fn srp_engine_matches_scalar_and_stays_read_only() {
        // SrpSketch::build is deterministic from (params, config), so a
        // second build is a bit-identical reference oracle.
        let kp = random_kp(6, 8, 5, 20);
        let reference = SrpSketch::build(&kp, &SketchConfig::default());
        let mut engine =
            SrpEngine::new(SrpSketch::build(&kp, &SketchConfig::default()));
        assert_eq!(engine.dim(), 8);
        let rows = random_rows(300, 9, 8);
        let got = engine.eval_batch(&rows).unwrap();
        let mut s = SrpScratch::default();
        for (i, r) in rows.iter().enumerate() {
            assert_eq!(
                got[i].to_bits(),
                reference.query_with(r, &mut s).to_bits(),
                "row {i}"
            );
        }
        // Bad dim is rejected, and the lane advertises immutability
        // (update traffic is refused, not dropped).
        assert!(engine.eval_batch(&[vec![0.0; 7]]).is_err());
        assert_eq!(engine.update_shape(), None);
        let up =
            UpdateRow { x: vec![0.0; 5], alpha: 1.0, class: 0 };
        assert!(engine.apply_updates(&[up], true).is_err());
    }

    fn multiclass_fixture(seed: u64, n_classes: usize)
        -> (FusedMultiSketch, MultiSketch, usize) {
        let mut rng = SplitMix64::new(seed);
        let d = 6usize;
        let shared_seed = rng.next_u64();
        let a: Vec<f32> =
            (0..d * d).map(|_| rng.next_gaussian() as f32 * 0.5).collect();
        let per_class: Vec<KernelParams> = (0..n_classes)
            .map(|_| {
                let m = 14;
                KernelParams {
                    d,
                    p: d,
                    m,
                    a: a.clone(),
                    x: (0..m * d)
                        .map(|_| rng.next_gaussian() as f32)
                        .collect(),
                    alpha: (0..m).map(|_| 0.5 + rng.next_f32()).collect(),
                    width: 2.0,
                    lsh_seed: shared_seed,
                    k_per_row: 2,
                    default_rows: 48,
                    default_cols: 16,
                }
            })
            .collect();
        let cfg = SketchConfig::default();
        (
            FusedMultiSketch::build(&per_class, &cfg).unwrap(),
            MultiSketch::build(&per_class, &cfg).unwrap(),
            d,
        )
    }

    #[test]
    fn multiclass_engine_matches_scalar_predict_across_par_threshold() {
        let (fused, ms, d) = multiclass_fixture(0xAC, 5);
        let pool = Arc::new(WorkerPool::new(4));
        let mut engine = MulticlassEngine::with_pool(fused, pool);
        let mut qs = QueryScratch::default();
        for &n in &[1usize, 30, 64, 67, 130] {
            let rows = random_rows(300 + n as u64, n, d);
            let got = engine.eval_batch(&rows).unwrap();
            assert_eq!(got.len(), n);
            for (i, r) in rows.iter().enumerate() {
                let want = ms.predict(r, &mut qs) as f32;
                assert_eq!(got[i], want, "n={n} row {i}");
            }
        }
    }

    #[test]
    fn multiclass_engine_rejects_bad_dim_rows() {
        let (fused, _, d) = multiclass_fixture(77, 3);
        let mut engine = MulticlassEngine::new(fused);
        assert!(engine.eval_batch(&[vec![0.0; d + 1]]).is_err());
    }

    #[test]
    fn multiclass_engine_returns_scores_on_request() {
        // Both sides of the fan-out threshold: values stay the argmax,
        // scores carry the full per-class vector, bit-identical to the
        // scalar reference.
        let (fused, ms, d) = multiclass_fixture(0x5C0, 4);
        let reference = fused.clone();
        let pool = Arc::new(WorkerPool::new(4));
        let mut engine = MulticlassEngine::with_pool(fused, pool);
        let mut fs = crate::sketch::FusedScratch::default();
        let mut want = Vec::new();
        for &n in &[1usize, 30, 130] {
            let rows = random_rows(400 + n as u64, n, d);
            let out = engine.eval_batch_ex(&rows, true).unwrap();
            let scores = out.scores.expect("scores requested");
            assert_eq!(out.values.len(), n);
            assert_eq!(scores.n_classes, 4);
            assert_eq!(scores.flat.len(), n * 4);
            let mut qs = QueryScratch::default();
            for (i, r) in rows.iter().enumerate() {
                reference.scores_with(r, &mut fs, &mut want);
                let row = scores.row(i).expect("row in range");
                for (c, w) in want.iter().enumerate() {
                    assert_eq!(
                        row[c].to_bits(),
                        w.to_bits(),
                        "n={n} row {i} class {c}"
                    );
                }
                assert_eq!(out.values[i], ms.predict(r, &mut qs) as f32);
            }
            // Without the flag: same values, no score materialization.
            let plain = engine.eval_batch_ex(&rows, false).unwrap();
            assert_eq!(plain.values, out.values);
            assert!(plain.scores.is_none());
        }
    }

    #[test]
    fn sharded_engine_single_output_matches_scalar_every_batch_shape() {
        let kp = random_kp(0x5A, 7, 4, 30);
        let sketch = crate::sketch::RaceSketch::build(
            &kp,
            &SketchConfig::default(),
        );
        let pool = Arc::new(WorkerPool::new(4));
        for &shards in &[1usize, 3, 8] {
            let sharded =
                crate::shard::ShardedSketch::from_race(&sketch, shards);
            let mut engine =
                ShardedEngine::with_pool(sharded, pool.clone());
            let mut s = QueryScratch::default();
            for &n in &[0usize, 1, 7, 64, 130] {
                let rows = random_rows(500 + n as u64, n, 7);
                let got = engine.eval_batch(&rows).unwrap();
                assert_eq!(got.len(), n);
                for (i, r) in rows.iter().enumerate() {
                    let want = sketch.query_with(r, &mut s);
                    assert_eq!(
                        got[i].to_bits(),
                        want.to_bits(),
                        "shards={shards} n={n} row {i}"
                    );
                }
            }
        }
    }

    #[test]
    fn sharded_engine_multiclass_matches_fused_and_serves_scores() {
        let (fused, ms, d) = multiclass_fixture(0x5B, 5);
        let reference = fused.clone();
        let pool = Arc::new(WorkerPool::new(4));
        let sharded = crate::shard::ShardedSketch::from_fused(&fused, 4);
        assert_eq!(sharded.n_shards(), 4);
        let mut engine = ShardedEngine::with_pool(sharded, pool);
        let rows = random_rows(0x5C, 33, d);
        let out = engine.eval_batch_ex(&rows, true).unwrap();
        let scores = out.scores.expect("scores requested");
        let mut fs = crate::sketch::FusedScratch::default();
        let mut qs = QueryScratch::default();
        let mut want = Vec::new();
        for (i, r) in rows.iter().enumerate() {
            reference.scores_with(r, &mut fs, &mut want);
            let row = scores.row(i).expect("row in range");
            for (c, w) in want.iter().enumerate() {
                assert_eq!(
                    row[c].to_bits(),
                    w.to_bits(),
                    "row {i} class {c}"
                );
            }
            assert_eq!(out.values[i], ms.predict(r, &mut qs) as f32);
        }
    }

    #[test]
    fn one_class_fused_sketch_answers_argmax_like_the_mc_lane() {
        // A C=1 RSFM served via `sh` must behave exactly like `mc`:
        // argmax index 0.0 (not the raw estimate), and a 1-long score
        // vector on request.  Only RSSK-shaped sketches answer raw
        // estimates.
        let (fused, _, d) = multiclass_fixture(0x5E, 1);
        let reference = fused.clone();
        let sharded = crate::shard::ShardedSketch::from_fused(&fused, 2);
        assert!(sharded.head.multiclass);
        let pool = Arc::new(WorkerPool::new(2));
        let mut engine = ShardedEngine::with_pool(sharded, pool.clone());
        let rows = random_rows(0x5F, 9, d);
        let out = engine.eval_batch_ex(&rows, true).unwrap();
        let scores = out.scores.expect("scores requested");
        assert_eq!(scores.n_classes, 1);
        let mut mc = MulticlassEngine::with_pool(reference, pool);
        let mc_out = mc.eval_batch_ex(&rows, true).unwrap();
        for i in 0..rows.len() {
            assert_eq!(out.values[i], 0.0, "argmax of one class");
            assert_eq!(out.values[i], mc_out.values[i]);
            assert_eq!(
                scores.row(i).unwrap()[0].to_bits(),
                mc_out.scores.as_ref().unwrap().row(i).unwrap()[0]
                    .to_bits(),
                "row {i}"
            );
        }
    }

    #[test]
    fn sharded_engine_rejects_bad_dim_rows() {
        let kp = random_kp(0x5D, 5, 5, 10);
        let sketch = crate::sketch::RaceSketch::build(
            &kp,
            &SketchConfig::default(),
        );
        let mut engine = ShardedEngine::new(
            crate::shard::ShardedSketch::from_race(&sketch, 2),
        );
        assert!(engine.eval_batch(&[vec![0.0; 4]]).is_err());
    }

    #[test]
    fn quant_engine_multiclass_matches_plane_kernel_across_threshold() {
        use crate::sketch::{GatherLanes, QuantBits, QuantScratch,
                            QuantSketch};
        let (fused, _, d) = multiclass_fixture(0xA5, 4);
        let qs = QuantSketch::from_fused(
            &fused,
            QuantBits::U8,
            GatherLanes::Lanes8,
        );
        let reference = QuantSketch::from_fused(
            &fused,
            QuantBits::U8,
            GatherLanes::Lanes8,
        );
        let tol = reference.score_tolerance();
        let pool = Arc::new(WorkerPool::new(4));
        let mut engine = QuantEngine::with_pool(qs, pool);
        assert_eq!(engine.update_shape(), None, "read-only lane");
        let mut s = QuantScratch::default();
        let mut fs = crate::sketch::FusedScratch::default();
        let mut f32_scores = Vec::new();
        for &n in &[1usize, 30, 64, 130] {
            let rows = random_rows(600 + n as u64, n, d);
            let out = engine.eval_batch_ex(&rows, true).unwrap();
            let scores = out.scores.expect("scores requested");
            assert_eq!(out.values.len(), n);
            assert_eq!(scores.n_classes, 4);
            for (i, r) in rows.iter().enumerate() {
                // Bit-identical to the plane kernel on both sides of
                // the fan-out threshold (B=1 IS the scalar path).
                let want = reference
                    .scores_batch_with(r, &mut s)
                    .to_vec();
                let row = scores.row(i).expect("row in range");
                for (c, w) in want.iter().enumerate() {
                    assert_eq!(
                        row[c].to_bits(),
                        w.to_bits(),
                        "n={n} row {i} class {c}"
                    );
                }
                // And inside the declared tolerance of the f32 lane.
                fused.scores_with(r, &mut fs, &mut f32_scores);
                for (c, w) in f32_scores.iter().enumerate() {
                    assert!(
                        (row[c] - w).abs() <= tol,
                        "n={n} row {i} class {c}: |{} - {w}| > {tol}",
                        row[c]
                    );
                }
            }
            // Without the flag: same argmax values, no matrix.
            let plain = engine.eval_batch_ex(&rows, false).unwrap();
            assert_eq!(plain.values, out.values);
            assert!(plain.scores.is_none());
        }
        // Updates are rejected (the default bail).
        let up = UpdateRow { x: vec![0.0; d], alpha: 1.0, class: 0 };
        assert!(engine.apply_updates(&[up], true).is_err());
    }

    #[test]
    fn quant_engine_single_output_answers_raw_estimates() {
        use crate::sketch::{GatherLanes, QuantBits, QuantScratch,
                            QuantSketch};
        let kp = random_kp(0xA6, 7, 4, 30);
        let sketch = crate::sketch::RaceSketch::build(
            &kp,
            &SketchConfig::default(),
        );
        let qs = QuantSketch::from_race(
            &sketch,
            QuantBits::U16,
            GatherLanes::Scalar,
        );
        let tol = qs.score_tolerance();
        let reference = QuantSketch::from_race(
            &sketch,
            QuantBits::U16,
            GatherLanes::Scalar,
        );
        let pool = Arc::new(WorkerPool::new(4));
        let mut engine = QuantEngine::with_pool(qs, pool);
        let mut s = QuantScratch::default();
        let mut qscr = QueryScratch::default();
        for &n in &[1usize, 64, 130] {
            let rows = random_rows(700 + n as u64, n, 7);
            let out = engine.eval_batch_ex(&rows, true).unwrap();
            assert!(out.scores.is_none(), "single-output: no matrix");
            for (i, r) in rows.iter().enumerate() {
                let want = reference.scores_batch_with(r, &mut s)[0];
                assert_eq!(
                    out.values[i].to_bits(),
                    want.to_bits(),
                    "n={n} row {i}"
                );
                let f = sketch.query_with(r, &mut qscr);
                assert!(
                    (out.values[i] - f).abs() <= tol,
                    "n={n} row {i}: |{} - {f}| > {tol}",
                    out.values[i]
                );
            }
        }
    }

    #[test]
    fn quantized_sharded_engine_is_read_only() {
        use crate::sketch::{GatherLanes, QuantBits, QuantSketch};
        let kp = random_kp(0xA7, 6, 4, 20);
        let sketch = crate::sketch::RaceSketch::build(
            &kp,
            &SketchConfig::default(),
        );
        let qs = QuantSketch::from_race(
            &sketch,
            QuantBits::U8,
            GatherLanes::Lanes8,
        );
        let sharded = crate::shard::ShardedSketch::from_quant(&qs, 3);
        let mut engine = ShardedEngine::new(sharded);
        assert_eq!(engine.update_shape(), None);
        let up = UpdateRow { x: vec![0.0; 4], alpha: 1.0, class: 0 };
        let err = engine.apply_updates(&[up], true).unwrap_err();
        assert!(err.to_string().contains("read-only"), "{err}");
        // Queries still serve (the empty f32 planes are benign).
        let rows = random_rows(0xA8, 5, 6);
        let got = engine.eval_batch(&rows).unwrap();
        assert_eq!(got.len(), 5);
    }

    #[test]
    fn immutable_engines_reject_updates() {
        let kp = random_kp(0xD0, 5, 3, 10);
        let mut engine = KernelEngine::new(KernelModel::new(kp));
        assert_eq!(engine.update_shape(), None);
        assert!(engine.plane_stats().is_none());
        let up = UpdateRow { x: vec![0.0; 3], alpha: 1.0, class: 0 };
        let err = engine.apply_updates(&[up], true).unwrap_err();
        assert!(err.to_string().contains("does not support updates"),
                "{err}");
    }

    /// Split `kp` at `m0`: the part the engine is built from, plus the
    /// tail streamed as live updates (support points are p-dimensional,
    /// so updates carry `x` rows of `kp.x` directly).
    fn split_updates(kp: &KernelParams, m0: usize)
        -> (KernelParams, Vec<UpdateRow>) {
        let mut part = kp.clone();
        part.m = m0;
        part.x.truncate(m0 * kp.p);
        part.alpha.truncate(m0);
        let ups = (m0..kp.m)
            .map(|j| UpdateRow {
                x: kp.x[j * kp.p..(j + 1) * kp.p].to_vec(),
                alpha: kp.alpha[j],
                class: 0,
            })
            .collect();
        (part, ups)
    }

    #[test]
    fn sketch_engine_streamed_updates_match_full_rebuild() {
        // An engine seeded with the first 20 support points and fed the
        // last 4 as live updates (one a delete — negative weight) must
        // answer bit-identically to an engine built from all 24 in one
        // pass: the epoch plane replays every delta into both buffers
        // in arrival order, so the f32 fold is the build's.
        let mut kp_full = random_kp(0xE0, 6, 4, 24);
        kp_full.alpha[22] = -kp_full.alpha[22]; // a streamed delete
        let cfg = SketchConfig::default();
        let (kp_part, ups) = split_updates(&kp_full, 20);
        let full = crate::sketch::RaceSketch::build(&kp_full, &cfg);
        let part = crate::sketch::RaceSketch::build(&kp_part, &cfg);
        let pool = Arc::new(WorkerPool::new(2));
        let mut engine = SketchEngine::with_pool(part, pool.clone());
        assert_eq!(engine.update_shape(), Some((4, 1)));
        let ack = engine.apply_updates(&ups, false).unwrap();
        assert_eq!(ack.epoch, 0, "no publish requested");
        assert_eq!(ack.pending, 4);
        let mut reference = SketchEngine::with_pool(full, pool);
        for &n in &[1usize, 9, 70] {
            let rows = random_rows(0xE1 + n as u64, n, 6);
            // eval publishes first (read-your-writes), so the very
            // first query already sees all four updates.
            let got = engine.eval_batch(&rows).unwrap();
            let want = reference.eval_batch(&rows).unwrap();
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert_eq!(g.to_bits(), w.to_bits(), "n={n} row {i}");
            }
        }
        let st = engine.plane_stats().expect("sketch lane has a plane");
        assert_eq!(
            st.updates.load(std::sync::atomic::Ordering::Relaxed),
            4
        );
        assert_eq!(
            st.pending.load(std::sync::atomic::Ordering::Relaxed),
            0,
            "eval published"
        );
        // Update validation: wrong dim, wrong class, non-finite weight.
        let bad = UpdateRow { x: vec![0.0; 3], alpha: 1.0, class: 0 };
        assert!(engine.apply_updates(&[bad], false).is_err());
        let bad = UpdateRow { x: vec![0.0; 4], alpha: 1.0, class: 1 };
        assert!(engine.apply_updates(&[bad], false).is_err());
        let bad =
            UpdateRow { x: vec![0.0; 4], alpha: f32::NAN, class: 0 };
        assert!(engine.apply_updates(&[bad], false).is_err());
    }

    #[test]
    fn multiclass_engine_streamed_updates_match_full_rebuild() {
        // Same contract through the fused per-class plane: stream class
        // 1's last four support points, compare scores bitwise against
        // the single-pass build.
        let mut rng = SplitMix64::new(0xE2);
        let d = 6usize;
        let shared_seed = rng.next_u64();
        let a: Vec<f32> = (0..d * d)
            .map(|_| rng.next_gaussian() as f32 * 0.5)
            .collect();
        let mut mk = |m: usize| KernelParams {
            d,
            p: d,
            m,
            a: a.clone(),
            x: (0..m * d).map(|_| rng.next_gaussian() as f32).collect(),
            alpha: (0..m).map(|_| 0.5 + rng.next_f32()).collect(),
            width: 2.0,
            lsh_seed: shared_seed,
            k_per_row: 2,
            default_rows: 48,
            default_cols: 16,
        };
        let per_class: Vec<KernelParams> =
            vec![mk(12), mk(14), mk(11)];
        let mut part = per_class.clone();
        part[1].m = 10;
        part[1].x.truncate(10 * d);
        part[1].alpha.truncate(10);
        let cfg = SketchConfig::default();
        let full = FusedMultiSketch::build(&per_class, &cfg).unwrap();
        let part = FusedMultiSketch::build(&part, &cfg).unwrap();
        let pool = Arc::new(WorkerPool::new(2));
        let mut engine = MulticlassEngine::with_pool(part, pool.clone());
        assert_eq!(engine.update_shape(), Some((d, 3)));
        let ups: Vec<UpdateRow> = (10..14)
            .map(|j| UpdateRow {
                x: per_class[1].x[j * d..(j + 1) * d].to_vec(),
                alpha: per_class[1].alpha[j],
                class: 1,
            })
            .collect();
        let ack = engine.apply_updates(&ups, true).unwrap();
        assert_eq!(ack.epoch, 1, "explicit publish bumps the epoch");
        assert_eq!(ack.pending, 0);
        let mut reference = MulticlassEngine::with_pool(full, pool);
        for &n in &[3usize, 70] {
            let rows = random_rows(0xE3 + n as u64, n, d);
            let got = engine.eval_batch_ex(&rows, true).unwrap();
            let want = reference.eval_batch_ex(&rows, true).unwrap();
            assert_eq!(got.values, want.values, "n={n}");
            let (gs, ws) = (got.scores.unwrap(), want.scores.unwrap());
            for (i, (g, w)) in
                gs.flat.iter().zip(&ws.flat).enumerate()
            {
                assert_eq!(g.to_bits(), w.to_bits(), "n={n} flat {i}");
            }
        }
        // Class out of range is a validation error, not a panic.
        let bad = UpdateRow { x: vec![0.0; d], alpha: 1.0, class: 3 };
        assert!(engine.apply_updates(&[bad], false).is_err());
    }

    #[test]
    fn sharded_engine_streamed_updates_match_monolithic() {
        // The lockstep per-shard planes must stay an exact carve of the
        // monolithic plane: stream updates through the sharded engine
        // and compare against a single-pass monolithic build.
        let kp_full = random_kp(0xE4, 7, 4, 30);
        let cfg = SketchConfig::default();
        let (kp_part, ups) = split_updates(&kp_full, 25);
        let full = crate::sketch::RaceSketch::build(&kp_full, &cfg);
        let part = crate::sketch::RaceSketch::build(&kp_part, &cfg);
        let pool = Arc::new(WorkerPool::new(4));
        let mut qs = QueryScratch::default();
        for &shards in &[1usize, 3] {
            let sharded =
                crate::shard::ShardedSketch::from_race(&part, shards);
            let mut engine =
                ShardedEngine::with_pool(sharded, pool.clone());
            assert_eq!(engine.update_shape(), Some((4, 1)));
            let ack = engine.apply_updates(&ups, true).unwrap();
            assert_eq!(ack.pending, 0);
            assert!(ack.epoch >= 1);
            for &n in &[1usize, 12] {
                let rows = random_rows(0xE5 + n as u64, n, 7);
                let got = engine.eval_batch(&rows).unwrap();
                for (i, r) in rows.iter().enumerate() {
                    let want = full.query_with(r, &mut qs);
                    assert_eq!(
                        got[i].to_bits(),
                        want.to_bits(),
                        "shards={shards} n={n} row {i}"
                    );
                }
            }
            let st =
                engine.plane_stats().expect("sh lane has planes");
            assert_eq!(
                st.updates.load(std::sync::atomic::Ordering::Relaxed),
                5
            );
        }
    }
}
