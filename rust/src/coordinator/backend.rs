//! Inference backends the router can dispatch to.
//!
//! Every dataset exposes up to five single-output variants — the exact
//! comparison matrix of the paper's evaluation — plus the multiclass
//! lane (§4.6):
//!
//! | kind      | engine                         | paper column |
//! |-----------|--------------------------------|--------------|
//! | `rs`      | RaceSketch (pure rust hot path)| RS           |
//! | `nn`      | rust dense MLP                 | NN           |
//! | `kernel`  | rust exact weighted KDE        | Kernel       |
//! | `nn-pjrt` | PJRT executable of nn.hlo.txt  | NN (XLA)     |
//! | `kernel-pjrt` | PJRT of kernel.hlo.txt (L1 Pallas) | Kernel (XLA) |
//! | `mc`      | FusedMultiSketch (class-interleaved) | — (§4.6) |
//!
//! A drained `DynamicBatcher` batch executes as ONE engine call: the
//! sketch lane runs the batch-major kernel
//! (`RaceSketch::query_batch_with`), the multiclass lane runs the fused
//! class-interleaved kernel (`FusedMultiSketch::predict_batch_with` —
//! one CSC hash walk and one contiguous gather serve the whole batch AND
//! all classes; responses carry the argmax class index).
//!
//! ## Parallel fan-out: the persistent sharded pool
//!
//! Batches of at least [`PAR_MIN_BATCH`] rows are split into contiguous
//! shards and executed on [`WorkerPool::shared`] — long-lived worker
//! threads with per-worker channel-fed queues and per-worker scratch
//! (see [`super::pool`]).  Nothing on the hot path spawns a thread: the
//! engines stage each shard's rows into an owned buffer, `Arc`-share the
//! model, and block until all shards report back.  Below the threshold
//! the lane thread runs the one batched kernel call itself with the
//! engine's own scratch.  Results are bit-identical to the per-row
//! scalar path regardless of batch size or shard count, so batching and
//! pooling are purely throughput knobs.

use super::pool::{WorkerPool, WorkerScratch};
use crate::kernel::KernelModel;
use crate::nn::{Mlp, MlpScratch};
use crate::runtime::Executable;
use crate::sketch::{BatchScratch, FusedMultiSketch, FusedScratch, RaceSketch};
use std::sync::Arc;

/// Which backend variant a request targets.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BackendKind {
    Sketch,
    NnRust,
    KernelRust,
    NnPjrt,
    KernelPjrt,
    Multiclass,
}

impl BackendKind {
    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Sketch => "rs",
            BackendKind::NnRust => "nn",
            BackendKind::KernelRust => "kernel",
            BackendKind::NnPjrt => "nn-pjrt",
            BackendKind::KernelPjrt => "kernel-pjrt",
            BackendKind::Multiclass => "mc",
        }
    }

    pub fn parse(s: &str) -> Option<BackendKind> {
        Some(match s {
            "rs" | "sketch" => BackendKind::Sketch,
            "nn" | "nn-rust" => BackendKind::NnRust,
            "kernel" | "kernel-rust" => BackendKind::KernelRust,
            "nn-pjrt" => BackendKind::NnPjrt,
            "kernel-pjrt" => BackendKind::KernelPjrt,
            "mc" | "multiclass" => BackendKind::Multiclass,
            _ => return None,
        })
    }

    pub const ALL: [BackendKind; 6] = [
        BackendKind::Sketch,
        BackendKind::NnRust,
        BackendKind::KernelRust,
        BackendKind::NnPjrt,
        BackendKind::KernelPjrt,
        BackendKind::Multiclass,
    ];
}

/// A batch-evaluating engine.  Instances are created *and used* on their
/// lane's worker thread (see `Router::add_lane`), so no `Send` bound —
/// which is what lets non-`Send` PJRT executables serve traffic.  CPU
/// engines fan large batches out to the shared [`WorkerPool`] (jobs own
/// their shard inputs, so only the job closures need `Send`).
pub trait Engine {
    /// Expected input dimensionality.
    fn dim(&self) -> usize;
    /// Evaluate a batch of feature rows into scalars.
    fn eval_batch(&mut self, rows: &[Vec<f32>]) -> anyhow::Result<Vec<f32>>;
}

/// Fan a batch out across the pool only when it is at least this large
/// (below this, one batched kernel call on the lane thread wins).
const PAR_MIN_BATCH: usize = 64;
/// Minimum rows per pool shard (handoff overhead floor).
const PAR_MIN_CHUNK: usize = 16;

/// Shard count for a batch of `n` rows on `pool`: enough shards to keep
/// each above `PAR_MIN_CHUNK` rows, never more than the pool's workers.
fn shard_count(pool: &WorkerPool, n: usize) -> usize {
    pool.workers().min(n / PAR_MIN_CHUNK).max(1)
}

/// Flatten `rows` (validated earlier) into contiguous per-shard buffers
/// of `chunk_rows` rows each.
fn shard_rows(rows: &[Vec<f32>], chunk_rows: usize, d: usize)
    -> Vec<Vec<f32>> {
    rows.chunks(chunk_rows)
        .map(|chunk| {
            let mut flat = Vec::with_capacity(chunk.len() * d);
            for r in chunk {
                flat.extend_from_slice(r);
            }
            flat
        })
        .collect()
}

/// RS hot path: batch-major sketch kernel, pool fan-out for big batches.
pub struct SketchEngine {
    pub sketch: Arc<RaceSketch>,
    pool: Arc<WorkerPool>,
    flat: Vec<f32>,
    scratch: BatchScratch,
}

impl SketchEngine {
    pub fn new(sketch: RaceSketch) -> Self {
        Self::with_pool(sketch, WorkerPool::shared())
    }

    pub fn with_pool(sketch: RaceSketch, pool: Arc<WorkerPool>) -> Self {
        Self {
            sketch: Arc::new(sketch),
            pool,
            flat: Vec::new(),
            scratch: BatchScratch::default(),
        }
    }
}

impl Engine for SketchEngine {
    fn dim(&self) -> usize {
        self.sketch.d
    }

    fn eval_batch(&mut self, rows: &[Vec<f32>]) -> anyhow::Result<Vec<f32>> {
        if rows.is_empty() {
            return Ok(Vec::new());
        }
        let d = self.sketch.d;
        for (i, r) in rows.iter().enumerate() {
            anyhow::ensure!(
                r.len() == d,
                "row {i} has dim {}, want {d}",
                r.len()
            );
        }
        let n = rows.len();
        let shards = shard_count(&self.pool, n);
        if n < PAR_MIN_BATCH || shards < 2 {
            // One batched kernel call on the lane thread, scratch reused.
            self.flat.clear();
            self.flat.reserve(n * d);
            for r in rows {
                self.flat.extend_from_slice(r);
            }
            return Ok(self
                .sketch
                .query_batch_with(&self.flat, &mut self.scratch)
                .to_vec());
        }
        // Sharded fan-out through the persistent pool: each shard job
        // owns its rows and runs the batched kernel with the worker's
        // resident scratch.  Per-query results are independent and the
        // batched path is bit-identical to scalar, so the split cannot
        // change answers.
        let chunk_rows = (n + shards - 1) / shards;
        let jobs: Vec<_> = shard_rows(rows, chunk_rows, d)
            .into_iter()
            .map(|flat| {
                let sketch = self.sketch.clone();
                move |ws: &mut WorkerScratch| {
                    sketch.query_batch_with(&flat, &mut ws.batch).to_vec()
                }
            })
            .collect();
        Ok(self.pool.run_jobs(jobs).concat())
    }
}

/// Rust dense MLP.
pub struct MlpEngine {
    pub mlp: Mlp,
    scratch: MlpScratch,
}

impl MlpEngine {
    pub fn new(mlp: Mlp) -> Self {
        Self { mlp, scratch: MlpScratch::default() }
    }
}

impl Engine for MlpEngine {
    fn dim(&self) -> usize {
        self.mlp.input_dim()
    }

    fn eval_batch(&mut self, rows: &[Vec<f32>]) -> anyhow::Result<Vec<f32>> {
        Ok(rows
            .iter()
            .map(|r| self.mlp.forward_with(r, &mut self.scratch))
            .collect())
    }
}

/// Rust exact weighted KDE (O(M·p) per row — the heaviest rust engine,
/// so large batches fan out across the pool).
pub struct KernelEngine {
    pub model: Arc<KernelModel>,
    pool: Arc<WorkerPool>,
}

impl KernelEngine {
    pub fn new(model: KernelModel) -> Self {
        Self::with_pool(model, WorkerPool::shared())
    }

    pub fn with_pool(model: KernelModel, pool: Arc<WorkerPool>) -> Self {
        Self { model: Arc::new(model), pool }
    }
}

impl Engine for KernelEngine {
    fn dim(&self) -> usize {
        self.model.params.d
    }

    fn eval_batch(&mut self, rows: &[Vec<f32>]) -> anyhow::Result<Vec<f32>> {
        let n = rows.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let d = self.model.params.d;
        for (i, r) in rows.iter().enumerate() {
            anyhow::ensure!(
                r.len() == d,
                "row {i} has dim {}, want {d}",
                r.len()
            );
        }
        let shards = shard_count(&self.pool, n);
        if n < PAR_MIN_BATCH || shards < 2 {
            return Ok(self.model.predict_batch(rows));
        }
        let chunk_rows = (n + shards - 1) / shards;
        let jobs: Vec<_> = shard_rows(rows, chunk_rows, d)
            .into_iter()
            .map(|flat| {
                let model = self.model.clone();
                move |_ws: &mut WorkerScratch| {
                    flat.chunks_exact(d)
                        .map(|r| model.predict(r))
                        .collect::<Vec<_>>()
                }
            })
            .collect();
        Ok(self.pool.run_jobs(jobs).concat())
    }
}

/// Multiclass lane: the fused class-interleaved sketch.  A drained batch
/// executes as ONE fused kernel call (one hash pass, one contiguous
/// gather for all C classes); responses carry the argmax class index as
/// an f32.
pub struct MulticlassEngine {
    pub fused: Arc<FusedMultiSketch>,
    pool: Arc<WorkerPool>,
    flat: Vec<f32>,
    scratch: FusedScratch,
    preds: Vec<usize>,
}

impl MulticlassEngine {
    pub fn new(fused: FusedMultiSketch) -> Self {
        Self::with_pool(fused, WorkerPool::shared())
    }

    pub fn with_pool(fused: FusedMultiSketch, pool: Arc<WorkerPool>)
        -> Self {
        Self {
            fused: Arc::new(fused),
            pool,
            flat: Vec::new(),
            scratch: FusedScratch::default(),
            preds: Vec::new(),
        }
    }
}

impl Engine for MulticlassEngine {
    fn dim(&self) -> usize {
        self.fused.d
    }

    fn eval_batch(&mut self, rows: &[Vec<f32>]) -> anyhow::Result<Vec<f32>> {
        if rows.is_empty() {
            return Ok(Vec::new());
        }
        let d = self.fused.d;
        for (i, r) in rows.iter().enumerate() {
            anyhow::ensure!(
                r.len() == d,
                "row {i} has dim {}, want {d}",
                r.len()
            );
        }
        let n = rows.len();
        let shards = shard_count(&self.pool, n);
        if n < PAR_MIN_BATCH || shards < 2 {
            self.flat.clear();
            self.flat.reserve(n * d);
            for r in rows {
                self.flat.extend_from_slice(r);
            }
            self.fused.predict_batch_with(
                &self.flat,
                &mut self.scratch,
                &mut self.preds,
            );
            return Ok(self.preds.iter().map(|&c| c as f32).collect());
        }
        let chunk_rows = (n + shards - 1) / shards;
        let jobs: Vec<_> = shard_rows(rows, chunk_rows, d)
            .into_iter()
            .map(|flat| {
                let fused = self.fused.clone();
                move |ws: &mut WorkerScratch| {
                    let mut preds = Vec::new();
                    fused.predict_batch_with(&flat, &mut ws.fused,
                                             &mut preds);
                    preds.into_iter().map(|c| c as f32).collect::<Vec<_>>()
                }
            })
            .collect();
        Ok(self.pool.run_jobs(jobs).concat())
    }
}

/// PJRT executable (AOT artifact).
pub struct PjrtEngine {
    pub exe: Executable,
}

impl Engine for PjrtEngine {
    fn dim(&self) -> usize {
        self.exe.dim
    }

    fn eval_batch(&mut self, rows: &[Vec<f32>]) -> anyhow::Result<Vec<f32>> {
        let mut out = Vec::with_capacity(rows.len());
        for chunk in rows.chunks(self.exe.batch) {
            let refs: Vec<&[f32]> =
                chunk.iter().map(|r| r.as_slice()).collect();
            out.extend(self.exe.run_batch(&refs)?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::KernelParams;
    use crate::sketch::{
        MultiSketch, QueryScratch, SketchConfig,
    };
    use crate::util::rng::SplitMix64;

    #[test]
    fn backend_kind_roundtrip() {
        for k in BackendKind::ALL {
            assert_eq!(BackendKind::parse(k.name()), Some(k));
        }
        assert_eq!(BackendKind::parse("multiclass"),
                   Some(BackendKind::Multiclass));
        assert_eq!(BackendKind::parse("bogus"), None);
    }

    fn random_kp(seed: u64, d: usize, p: usize, m: usize) -> KernelParams {
        let mut rng = SplitMix64::new(seed);
        KernelParams {
            d,
            p,
            m,
            a: (0..d * p).map(|_| rng.next_gaussian() as f32 * 0.5).collect(),
            x: (0..m * p).map(|_| rng.next_gaussian() as f32).collect(),
            alpha: (0..m).map(|_| 0.5 + rng.next_f32()).collect(),
            width: 2.0,
            lsh_seed: rng.next_u64(),
            k_per_row: 2,
            default_rows: 64,
            default_cols: 16,
        }
    }

    fn random_rows(seed: u64, n: usize, d: usize) -> Vec<Vec<f32>> {
        let mut rng = SplitMix64::new(seed);
        (0..n)
            .map(|_| (0..d).map(|_| rng.next_gaussian() as f32).collect())
            .collect()
    }

    #[test]
    fn sketch_engine_matches_scalar_for_all_batch_shapes() {
        // Covers the single-call path (< PAR_MIN_BATCH), the pool
        // fan-out path, and ragged final shards in both.
        let kp = random_kp(3, 7, 4, 30);
        let sketch = crate::sketch::RaceSketch::build(
            &kp,
            &SketchConfig::default(),
        );
        let pool = Arc::new(WorkerPool::new(4));
        let mut engine = SketchEngine::with_pool(sketch.clone(), pool);
        let mut s = QueryScratch::default();
        for &n in &[0usize, 1, 7, 63, 64, 67, 130, 257] {
            let rows = random_rows(100 + n as u64, n, 7);
            let got = engine.eval_batch(&rows).unwrap();
            assert_eq!(got.len(), n);
            for (i, r) in rows.iter().enumerate() {
                let want = sketch.query_with(r, &mut s);
                assert_eq!(got[i].to_bits(), want.to_bits(), "n={n} row {i}");
            }
        }
    }

    #[test]
    fn sketch_engine_rejects_bad_dim_rows() {
        let kp = random_kp(4, 5, 5, 10);
        let mut engine = SketchEngine::new(crate::sketch::RaceSketch::build(
            &kp,
            &SketchConfig::default(),
        ));
        assert!(engine.eval_batch(&[vec![0.0; 4]]).is_err());
    }

    #[test]
    fn kernel_engine_matches_scalar_across_par_threshold() {
        let kp = random_kp(5, 6, 3, 20);
        let reference = KernelModel::new(kp.clone());
        let pool = Arc::new(WorkerPool::new(4));
        let mut engine =
            KernelEngine::with_pool(KernelModel::new(kp), pool);
        for &n in &[1usize, 65, 130] {
            let rows = random_rows(200 + n as u64, n, 6);
            let got = engine.eval_batch(&rows).unwrap();
            for (i, r) in rows.iter().enumerate() {
                assert_eq!(
                    got[i].to_bits(),
                    reference.predict(r).to_bits(),
                    "n={n} row {i}"
                );
            }
        }
    }

    fn multiclass_fixture(seed: u64, n_classes: usize)
        -> (FusedMultiSketch, MultiSketch, usize) {
        let mut rng = SplitMix64::new(seed);
        let d = 6usize;
        let shared_seed = rng.next_u64();
        let a: Vec<f32> =
            (0..d * d).map(|_| rng.next_gaussian() as f32 * 0.5).collect();
        let per_class: Vec<KernelParams> = (0..n_classes)
            .map(|_| {
                let m = 14;
                KernelParams {
                    d,
                    p: d,
                    m,
                    a: a.clone(),
                    x: (0..m * d)
                        .map(|_| rng.next_gaussian() as f32)
                        .collect(),
                    alpha: (0..m).map(|_| 0.5 + rng.next_f32()).collect(),
                    width: 2.0,
                    lsh_seed: shared_seed,
                    k_per_row: 2,
                    default_rows: 48,
                    default_cols: 16,
                }
            })
            .collect();
        let cfg = SketchConfig::default();
        (
            FusedMultiSketch::build(&per_class, &cfg).unwrap(),
            MultiSketch::build(&per_class, &cfg).unwrap(),
            d,
        )
    }

    #[test]
    fn multiclass_engine_matches_scalar_predict_across_par_threshold() {
        let (fused, ms, d) = multiclass_fixture(0xAC, 5);
        let pool = Arc::new(WorkerPool::new(4));
        let mut engine = MulticlassEngine::with_pool(fused, pool);
        let mut qs = QueryScratch::default();
        for &n in &[1usize, 30, 64, 67, 130] {
            let rows = random_rows(300 + n as u64, n, d);
            let got = engine.eval_batch(&rows).unwrap();
            assert_eq!(got.len(), n);
            for (i, r) in rows.iter().enumerate() {
                let want = ms.predict(r, &mut qs) as f32;
                assert_eq!(got[i], want, "n={n} row {i}");
            }
        }
    }

    #[test]
    fn multiclass_engine_rejects_bad_dim_rows() {
        let (fused, _, d) = multiclass_fixture(77, 3);
        let mut engine = MulticlassEngine::new(fused);
        assert!(engine.eval_batch(&[vec![0.0; d + 1]]).is_err());
    }
}
