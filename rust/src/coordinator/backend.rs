//! Inference backends the router can dispatch to.
//!
//! Every dataset exposes up to five variants — the exact comparison
//! matrix of the paper's evaluation:
//!
//! | kind      | engine                         | paper column |
//! |-----------|--------------------------------|--------------|
//! | `rs`      | RaceSketch (pure rust hot path)| RS           |
//! | `nn`      | rust dense MLP                 | NN           |
//! | `kernel`  | rust exact weighted KDE        | Kernel       |
//! | `nn-pjrt` | PJRT executable of nn.hlo.txt  | NN (XLA)     |
//! | `kernel-pjrt` | PJRT of kernel.hlo.txt (L1 Pallas) | Kernel (XLA) |

use crate::kernel::KernelModel;
use crate::nn::{Mlp, MlpScratch};
use crate::runtime::Executable;
use crate::sketch::{QueryScratch, RaceSketch};

/// Which backend variant a request targets.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BackendKind {
    Sketch,
    NnRust,
    KernelRust,
    NnPjrt,
    KernelPjrt,
}

impl BackendKind {
    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Sketch => "rs",
            BackendKind::NnRust => "nn",
            BackendKind::KernelRust => "kernel",
            BackendKind::NnPjrt => "nn-pjrt",
            BackendKind::KernelPjrt => "kernel-pjrt",
        }
    }

    pub fn parse(s: &str) -> Option<BackendKind> {
        Some(match s {
            "rs" | "sketch" => BackendKind::Sketch,
            "nn" | "nn-rust" => BackendKind::NnRust,
            "kernel" | "kernel-rust" => BackendKind::KernelRust,
            "nn-pjrt" => BackendKind::NnPjrt,
            "kernel-pjrt" => BackendKind::KernelPjrt,
            _ => return None,
        })
    }

    pub const ALL: [BackendKind; 5] = [
        BackendKind::Sketch,
        BackendKind::NnRust,
        BackendKind::KernelRust,
        BackendKind::NnPjrt,
        BackendKind::KernelPjrt,
    ];
}

/// A batch-evaluating engine.  Instances are created *and used* on their
/// lane's worker thread (see `Router::add_lane`), so no `Send` bound —
/// which is what lets non-`Send` PJRT executables serve traffic.
pub trait Engine {
    /// Expected input dimensionality.
    fn dim(&self) -> usize;
    /// Evaluate a batch of feature rows into scalars.
    fn eval_batch(&mut self, rows: &[Vec<f32>]) -> anyhow::Result<Vec<f32>>;
}

/// RS hot path.
pub struct SketchEngine {
    pub sketch: RaceSketch,
    scratch: QueryScratch,
}

impl SketchEngine {
    pub fn new(sketch: RaceSketch) -> Self {
        Self { sketch, scratch: QueryScratch::default() }
    }
}

impl Engine for SketchEngine {
    fn dim(&self) -> usize {
        self.sketch.d
    }

    fn eval_batch(&mut self, rows: &[Vec<f32>]) -> anyhow::Result<Vec<f32>> {
        Ok(rows
            .iter()
            .map(|r| self.sketch.query_with(r, &mut self.scratch))
            .collect())
    }
}

/// Rust dense MLP.
pub struct MlpEngine {
    pub mlp: Mlp,
    scratch: MlpScratch,
}

impl MlpEngine {
    pub fn new(mlp: Mlp) -> Self {
        Self { mlp, scratch: MlpScratch::default() }
    }
}

impl Engine for MlpEngine {
    fn dim(&self) -> usize {
        self.mlp.input_dim()
    }

    fn eval_batch(&mut self, rows: &[Vec<f32>]) -> anyhow::Result<Vec<f32>> {
        Ok(rows
            .iter()
            .map(|r| self.mlp.forward_with(r, &mut self.scratch))
            .collect())
    }
}

/// Rust exact weighted KDE.
pub struct KernelEngine {
    pub model: KernelModel,
}

impl Engine for KernelEngine {
    fn dim(&self) -> usize {
        self.model.params.d
    }

    fn eval_batch(&mut self, rows: &[Vec<f32>]) -> anyhow::Result<Vec<f32>> {
        Ok(rows.iter().map(|r| self.model.predict(r)).collect())
    }
}

/// PJRT executable (AOT artifact).
pub struct PjrtEngine {
    pub exe: Executable,
}

impl Engine for PjrtEngine {
    fn dim(&self) -> usize {
        self.exe.dim
    }

    fn eval_batch(&mut self, rows: &[Vec<f32>]) -> anyhow::Result<Vec<f32>> {
        let mut out = Vec::with_capacity(rows.len());
        for chunk in rows.chunks(self.exe.batch) {
            let refs: Vec<&[f32]> =
                chunk.iter().map(|r| r.as_slice()).collect();
            out.extend(self.exe.run_batch(&refs)?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_roundtrip() {
        for k in BackendKind::ALL {
            assert_eq!(BackendKind::parse(k.name()), Some(k));
        }
        assert_eq!(BackendKind::parse("bogus"), None);
    }
}
