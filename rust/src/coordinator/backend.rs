//! Inference backends the router can dispatch to.
//!
//! Every dataset exposes up to five variants — the exact comparison
//! matrix of the paper's evaluation:
//!
//! | kind      | engine                         | paper column |
//! |-----------|--------------------------------|--------------|
//! | `rs`      | RaceSketch (pure rust hot path)| RS           |
//! | `nn`      | rust dense MLP                 | NN           |
//! | `kernel`  | rust exact weighted KDE        | Kernel       |
//! | `nn-pjrt` | PJRT executable of nn.hlo.txt  | NN (XLA)     |
//! | `kernel-pjrt` | PJRT of kernel.hlo.txt (L1 Pallas) | Kernel (XLA) |
//!
//! A drained `DynamicBatcher` batch executes as ONE engine call.  The
//! sketch engine forwards it to the batch-major kernel
//! (`RaceSketch::query_batch_with` — one CSC hash walk serving the whole
//! batch), and both the sketch and exact-kernel engines split large
//! batches across cores with a chunked `std::thread::scope` fan-out.
//! Results are bit-identical to the per-row scalar path regardless of
//! batch size or worker count, so batching is purely a throughput knob.

use crate::kernel::KernelModel;
use crate::nn::{Mlp, MlpScratch};
use crate::runtime::Executable;
use crate::sketch::{BatchScratch, RaceSketch};

/// Which backend variant a request targets.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BackendKind {
    Sketch,
    NnRust,
    KernelRust,
    NnPjrt,
    KernelPjrt,
}

impl BackendKind {
    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Sketch => "rs",
            BackendKind::NnRust => "nn",
            BackendKind::KernelRust => "kernel",
            BackendKind::NnPjrt => "nn-pjrt",
            BackendKind::KernelPjrt => "kernel-pjrt",
        }
    }

    pub fn parse(s: &str) -> Option<BackendKind> {
        Some(match s {
            "rs" | "sketch" => BackendKind::Sketch,
            "nn" | "nn-rust" => BackendKind::NnRust,
            "kernel" | "kernel-rust" => BackendKind::KernelRust,
            "nn-pjrt" => BackendKind::NnPjrt,
            "kernel-pjrt" => BackendKind::KernelPjrt,
            _ => return None,
        })
    }

    pub const ALL: [BackendKind; 5] = [
        BackendKind::Sketch,
        BackendKind::NnRust,
        BackendKind::KernelRust,
        BackendKind::NnPjrt,
        BackendKind::KernelPjrt,
    ];
}

/// A batch-evaluating engine.  Instances are created *and used* on their
/// lane's worker thread (see `Router::add_lane`), so no `Send` bound —
/// which is what lets non-`Send` PJRT executables serve traffic.
pub trait Engine {
    /// Expected input dimensionality.
    fn dim(&self) -> usize;
    /// Evaluate a batch of feature rows into scalars.
    fn eval_batch(&mut self, rows: &[Vec<f32>]) -> anyhow::Result<Vec<f32>>;
}

/// Fan a batch out across cores only when it is at least this large
/// (below this, one batched kernel call on the lane thread wins).
const PAR_MIN_BATCH: usize = 64;
/// Minimum rows per worker thread (spawn overhead floor).
const PAR_MIN_CHUNK: usize = 16;

/// Worker-thread count for a batch of `n` rows: enough cores to keep
/// every worker above `PAR_MIN_CHUNK` rows, never more than the machine.
fn worker_count(n: usize) -> usize {
    let cores = std::thread::available_parallelism()
        .map(|c| c.get())
        .unwrap_or(1);
    cores.min(n / PAR_MIN_CHUNK).max(1)
}

/// RS hot path: batch-major sketch kernel with chunked parallel fan-out.
pub struct SketchEngine {
    pub sketch: RaceSketch,
    flat: Vec<f32>,
    scratch: BatchScratch,
}

impl SketchEngine {
    pub fn new(sketch: RaceSketch) -> Self {
        Self { sketch, flat: Vec::new(), scratch: BatchScratch::default() }
    }
}

impl Engine for SketchEngine {
    fn dim(&self) -> usize {
        self.sketch.d
    }

    fn eval_batch(&mut self, rows: &[Vec<f32>]) -> anyhow::Result<Vec<f32>> {
        if rows.is_empty() {
            return Ok(Vec::new());
        }
        let d = self.sketch.d;
        self.flat.clear();
        self.flat.reserve(rows.len() * d);
        for (i, r) in rows.iter().enumerate() {
            anyhow::ensure!(
                r.len() == d,
                "row {i} has dim {}, want {d}",
                r.len()
            );
            self.flat.extend_from_slice(r);
        }
        let n = rows.len();
        let workers = worker_count(n);
        if n < PAR_MIN_BATCH || workers < 2 {
            // One batched kernel call on the lane thread, scratch reused.
            return Ok(self
                .sketch
                .query_batch_with(&self.flat, &mut self.scratch)
                .to_vec());
        }
        // Chunked fan-out: each worker runs the batched kernel on a
        // contiguous row range.  Per-query results are independent and
        // the batched path is bit-identical to scalar, so the split
        // cannot change answers.
        let chunk_rows = (n + workers - 1) / workers;
        let mut out = vec![0.0f32; n];
        let sketch = &self.sketch;
        let flat = &self.flat;
        std::thread::scope(|scope| {
            for (qchunk, ochunk) in flat
                .chunks(chunk_rows * d)
                .zip(out.chunks_mut(chunk_rows))
            {
                scope.spawn(move || {
                    let mut scratch = BatchScratch::default();
                    let res = sketch.query_batch_with(qchunk, &mut scratch);
                    ochunk.copy_from_slice(res);
                });
            }
        });
        Ok(out)
    }
}

/// Rust dense MLP.
pub struct MlpEngine {
    pub mlp: Mlp,
    scratch: MlpScratch,
}

impl MlpEngine {
    pub fn new(mlp: Mlp) -> Self {
        Self { mlp, scratch: MlpScratch::default() }
    }
}

impl Engine for MlpEngine {
    fn dim(&self) -> usize {
        self.mlp.input_dim()
    }

    fn eval_batch(&mut self, rows: &[Vec<f32>]) -> anyhow::Result<Vec<f32>> {
        Ok(rows
            .iter()
            .map(|r| self.mlp.forward_with(r, &mut self.scratch))
            .collect())
    }
}

/// Rust exact weighted KDE (O(M·p) per row — the heaviest rust engine,
/// so large batches fan out across cores).
pub struct KernelEngine {
    pub model: KernelModel,
}

impl Engine for KernelEngine {
    fn dim(&self) -> usize {
        self.model.params.d
    }

    fn eval_batch(&mut self, rows: &[Vec<f32>]) -> anyhow::Result<Vec<f32>> {
        let n = rows.len();
        let workers = worker_count(n);
        if n < PAR_MIN_BATCH || workers < 2 {
            return Ok(self.model.predict_batch(rows));
        }
        let chunk_rows = (n + workers - 1) / workers;
        let mut out = vec![0.0f32; n];
        let model = &self.model;
        std::thread::scope(|scope| {
            for (rchunk, ochunk) in
                rows.chunks(chunk_rows).zip(out.chunks_mut(chunk_rows))
            {
                scope.spawn(move || {
                    for (o, r) in ochunk.iter_mut().zip(rchunk) {
                        *o = model.predict(r);
                    }
                });
            }
        });
        Ok(out)
    }
}

/// PJRT executable (AOT artifact).
pub struct PjrtEngine {
    pub exe: Executable,
}

impl Engine for PjrtEngine {
    fn dim(&self) -> usize {
        self.exe.dim
    }

    fn eval_batch(&mut self, rows: &[Vec<f32>]) -> anyhow::Result<Vec<f32>> {
        let mut out = Vec::with_capacity(rows.len());
        for chunk in rows.chunks(self.exe.batch) {
            let refs: Vec<&[f32]> =
                chunk.iter().map(|r| r.as_slice()).collect();
            out.extend(self.exe.run_batch(&refs)?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::KernelParams;
    use crate::sketch::{QueryScratch, SketchConfig};
    use crate::util::rng::SplitMix64;

    #[test]
    fn backend_kind_roundtrip() {
        for k in BackendKind::ALL {
            assert_eq!(BackendKind::parse(k.name()), Some(k));
        }
        assert_eq!(BackendKind::parse("bogus"), None);
    }

    fn random_kp(seed: u64, d: usize, p: usize, m: usize) -> KernelParams {
        let mut rng = SplitMix64::new(seed);
        KernelParams {
            d,
            p,
            m,
            a: (0..d * p).map(|_| rng.next_gaussian() as f32 * 0.5).collect(),
            x: (0..m * p).map(|_| rng.next_gaussian() as f32).collect(),
            alpha: (0..m).map(|_| 0.5 + rng.next_f32()).collect(),
            width: 2.0,
            lsh_seed: rng.next_u64(),
            k_per_row: 2,
            default_rows: 64,
            default_cols: 16,
        }
    }

    fn random_rows(seed: u64, n: usize, d: usize) -> Vec<Vec<f32>> {
        let mut rng = SplitMix64::new(seed);
        (0..n)
            .map(|_| (0..d).map(|_| rng.next_gaussian() as f32).collect())
            .collect()
    }

    #[test]
    fn sketch_engine_matches_scalar_for_all_batch_shapes() {
        // Covers the single-call path (< PAR_MIN_BATCH), the parallel
        // fan-out path, and ragged final chunks in both.
        let kp = random_kp(3, 7, 4, 30);
        let sketch = crate::sketch::RaceSketch::build(
            &kp,
            &SketchConfig::default(),
        );
        let mut engine = SketchEngine::new(sketch.clone());
        let mut s = QueryScratch::default();
        for &n in &[0usize, 1, 7, 63, 64, 67, 130, 257] {
            let rows = random_rows(100 + n as u64, n, 7);
            let got = engine.eval_batch(&rows).unwrap();
            assert_eq!(got.len(), n);
            for (i, r) in rows.iter().enumerate() {
                let want = sketch.query_with(r, &mut s);
                assert_eq!(got[i].to_bits(), want.to_bits(), "n={n} row {i}");
            }
        }
    }

    #[test]
    fn sketch_engine_rejects_bad_dim_rows() {
        let kp = random_kp(4, 5, 5, 10);
        let mut engine = SketchEngine::new(crate::sketch::RaceSketch::build(
            &kp,
            &SketchConfig::default(),
        ));
        assert!(engine.eval_batch(&[vec![0.0; 4]]).is_err());
    }

    #[test]
    fn kernel_engine_matches_scalar_across_par_threshold() {
        let kp = random_kp(5, 6, 3, 20);
        let model = KernelModel::new(kp);
        let reference = KernelModel::new(model.params.clone());
        let mut engine = KernelEngine { model };
        for &n in &[1usize, 65, 130] {
            let rows = random_rows(200 + n as u64, n, 6);
            let got = engine.eval_batch(&rows).unwrap();
            for (i, r) in rows.iter().enumerate() {
                assert_eq!(
                    got[i].to_bits(),
                    reference.predict(r).to_bits(),
                    "n={n} row {i}"
                );
            }
        }
    }
}
