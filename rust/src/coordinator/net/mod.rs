//! Event-driven TCP front-end (Linux): a tokio-free epoll reactor.
//!
//! Layout:
//! - [`sys`]: the raw syscall surface (`epoll_create1` / `epoll_ctl` /
//!   `epoll_wait` / `fcntl` / `pipe`) declared via `extern "C"` against
//!   the already-linked libc — no registry crates, per the offline
//!   image constraint.
//! - [`conn`]: per-connection state — incremental framing (JSON lines
//!   with a hard [`conn::MAX_LINE_BYTES`] cap, and length-prefixed
//!   binary frames with a configurable payload cap), buffered
//!   nonblocking writes, in-flight accounting for deferred close.
//! - [`frame`]: the binary frame header — magic, version, verb,
//!   request id, declared payload length (see the wire-format spec
//!   below and in `shard::mod`).
//! - [`reactor`]: the event loop plus [`CompletionSender`], the
//!   wake-pipe completion path that replaced the seed's
//!   thread-per-in-flight-request forwarders.  The reactor is
//!   protocol-agnostic over a [`LineHandler`]: the inference plane's
//!   `Router` serves JSON lines, the shard plane's
//!   `shard::remote::ShardService` serves both wires behind the same
//!   event loop, and the remote-shard client reuses [`conn::Conn`] +
//!   [`sys::Epoll`] from the other side of the wire.
//!
//! The non-Linux thread-per-connection fallback lives in
//! `coordinator::server` (compiled out of Linux builds).
//!
//! # Wire framing invariants
//!
//! Two framings share one reactor; [`conn::WireMode`] selects per
//! listener, and `Auto` sniffs per connection from the first byte
//! (binary frames start with `b'R'` of `"RSBF"`; JSON lines start
//! with `{`, a digit, or whitespace — never `R`):
//!
//! - **Lines** (`\n`-delimited JSON): a line over
//!   [`conn::MAX_LINE_BYTES`] is discarded as it streams — never
//!   buffered — while a constant-memory matcher ([`conn::IdScan`])
//!   recovers the request id from anywhere in the line, so the error
//!   answer correlates even when a megabyte `"x"` array precedes the
//!   `"id"` key.  Exactly one error per oversize line, emitted when
//!   the line ends (newline or EOF); the connection survives.
//! - **Frames** (20-byte header + raw payload, all integers
//!   little-endian; layout in [`frame`]): the declared payload length
//!   is validated against the frame cap BEFORE any payload byte is
//!   buffered.  An over-cap frame is answered with an error frame
//!   naming the request id and its payload is discarded byte-exactly;
//!   the connection survives.  A corrupt header (bad magic, version,
//!   or reserved bytes) is answered once and the connection closed —
//!   a byte stream cannot be resynchronized past a bad length prefix.
//! - **Write cap**: a single response that cannot fit under the
//!   per-connection write cap at all is refused with a descriptive
//!   per-request error in the same wire format; only a *cumulative*
//!   backlog over the cap (a client not reading) tears the connection
//!   down.
//! - **Version negotiation** happens in the service-level `hello`
//!   exchange (same JSON document on both wires), not in the frame
//!   header: the header version byte only gates header *layout*
//!   changes, and a mismatch is a descriptive reject.
//!
//! # Invariants catalog
//!
//! The `repsketch-audit` gate (see [`crate::audit`]) enforces the
//! *annotations*; this catalog states the *invariants* the annotations
//! attest to.  Every rule below is checked mechanically on each build —
//! a violation fails CI with a `file:line` finding.
//!
//! 1. **Syscall confinement.** All `extern "C"` declarations live in
//!    [`sys`] and nowhere else.  Every fallible syscall either has its
//!    return value checked, or carries an `// ERRNO:` comment stating
//!    why the error is unactionable at that site (e.g. `close` on a
//!    teardown path where the fd is forfeit either way).
//!
//! 2. **Unsafe is justified.** Every `unsafe` block or fn in the tree
//!    carries a `// SAFETY:` comment naming the precondition that makes
//!    it sound (valid fd, live pointer, signal-handler constraints).
//!    The reactor's safety story is confined to the [`sys`] wrappers;
//!    [`conn`], [`frame`], and [`reactor`] are safe code over those
//!    wrappers.
//!
//! 3. **Memory orderings are explained.** Every `Ordering::*` use
//!    carries an `// ORDERING:` comment naming its pairing: stop flags
//!    are Release-store / Acquire-load pairs (reactor loop vs.
//!    stop-handle), stat counters are Relaxed (monotonic, sampled only
//!    for reporting), and the epoch plane's full protocol is documented
//!    in [`crate::sketch::epoch`].  `SeqCst` additionally requires a
//!    `seqcst-required` justification — there are currently zero such
//!    sites.
//!
//! 4. **Wire integers are checked.** In the wire-facing files
//!    (`coordinator/protocol.rs`, `coordinator/net/frame.rs`,
//!    `coordinator/net/conn.rs`, `shard/remote.rs`, `shard/serde.rs`,
//!    `util/json.rs`) every `as` numeric cast is either replaced with
//!    `try_from` surfacing a descriptive error, or carries a `// CAST:`
//!    comment proving losslessness (widening, bounds-checked, or
//!    explicitly tolerated rounding in latency reports).
//!
//! 5. **The hot path does not panic.** In the serve-path files
//!    (reactor, conn, frame, sys, pool, shard/remote) `panic!` /
//!    `unwrap` / `expect` require a `// PANIC:` justification — allowed
//!    only for construction-time setup, mutex poison (a prior panic
//!    already tearing the process down), and stated invariants.
//!
//! 6. **The epoch plane is schedule-checked.** The RCU counter-plane
//!    protocol behind live updates is exercised by
//!    [`crate::audit::interleave`]: every feasible two-thread
//!    interleaving (plus seeded three-thread walks) must leave pinned
//!    snapshots bitwise identical to a single-pass rebuild.  The
//!    battery runs in `cargo test` and in `tests/audit_interleave.rs`.

pub mod conn;
pub mod frame;
pub mod reactor;
pub mod sys;

pub use conn::WireMode;
pub use reactor::{CompletionSender, LineHandler, NetOptions, Reactor};
