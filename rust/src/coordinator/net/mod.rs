//! Event-driven TCP front-end (Linux): a tokio-free epoll reactor.
//!
//! Layout:
//! - [`sys`]: the raw syscall surface (`epoll_create1` / `epoll_ctl` /
//!   `epoll_wait` / `fcntl` / `pipe`) declared via `extern "C"` against
//!   the already-linked libc — no registry crates, per the offline
//!   image constraint.
//! - [`conn`]: per-connection state — incremental line framing with a
//!   hard [`conn::MAX_LINE_BYTES`] cap (the OOM fix), buffered
//!   nonblocking writes, in-flight accounting for deferred close.
//! - [`reactor`]: the event loop plus [`CompletionSender`], the
//!   wake-pipe completion path that replaced the seed's
//!   thread-per-in-flight-request forwarders.  The reactor is
//!   line-protocol-agnostic over a [`LineHandler`]: the inference
//!   plane's `Router` and the shard plane's
//!   `shard::remote::ShardService` both serve behind the same event
//!   loop, and the remote-shard client reuses [`conn::Conn`] +
//!   [`sys::Epoll`] from the other side of the wire.
//!
//! The non-Linux thread-per-connection fallback lives in
//! `coordinator::server` (compiled out of Linux builds).

pub mod conn;
pub mod reactor;
pub mod sys;

pub use reactor::{CompletionSender, LineHandler, Reactor};
