//! Event-driven TCP front-end (Linux): a tokio-free epoll reactor.
//!
//! Layout:
//! - [`sys`]: the raw syscall surface (`epoll_create1` / `epoll_ctl` /
//!   `epoll_wait` / `fcntl` / `pipe`) declared via `extern "C"` against
//!   the already-linked libc — no registry crates, per the offline
//!   image constraint.
//! - [`conn`]: per-connection state — incremental line framing with a
//!   hard [`conn::MAX_LINE_BYTES`] cap (the OOM fix), buffered
//!   nonblocking writes, in-flight accounting for deferred close.
//! - [`reactor`]: the event loop plus [`CompletionSender`], the
//!   wake-pipe completion path that replaced the seed's
//!   thread-per-in-flight-request forwarders.  The reactor is
//!   line-protocol-agnostic over a [`LineHandler`]: the inference
//!   plane's `Router` and the shard plane's
//!   `shard::remote::ShardService` both serve behind the same event
//!   loop, and the remote-shard client reuses [`conn::Conn`] +
//!   [`sys::Epoll`] from the other side of the wire.
//!
//! The non-Linux thread-per-connection fallback lives in
//! `coordinator::server` (compiled out of Linux builds).
//!
//! # Invariants catalog
//!
//! The `repsketch-audit` gate (see [`crate::audit`]) enforces the
//! *annotations*; this catalog states the *invariants* the annotations
//! attest to.  Every rule below is checked mechanically on each build —
//! a violation fails CI with a `file:line` finding.
//!
//! 1. **Syscall confinement.** All `extern "C"` declarations live in
//!    [`sys`] and nowhere else.  Every fallible syscall either has its
//!    return value checked, or carries an `// ERRNO:` comment stating
//!    why the error is unactionable at that site (e.g. `close` on a
//!    teardown path where the fd is forfeit either way).
//!
//! 2. **Unsafe is justified.** Every `unsafe` block or fn in the tree
//!    carries a `// SAFETY:` comment naming the precondition that makes
//!    it sound (valid fd, live pointer, signal-handler constraints).
//!    The reactor's safety story is confined to the [`sys`] wrappers;
//!    [`conn`] and [`reactor`] are safe code over those wrappers.
//!
//! 3. **Memory orderings are explained.** Every `Ordering::*` use
//!    carries an `// ORDERING:` comment naming its pairing: stop flags
//!    are Release-store / Acquire-load pairs (reactor loop vs.
//!    stop-handle), stat counters are Relaxed (monotonic, sampled only
//!    for reporting), and the epoch plane's full protocol is documented
//!    in [`crate::sketch::epoch`].  `SeqCst` additionally requires a
//!    `seqcst-required` justification — there are currently zero such
//!    sites.
//!
//! 4. **Wire integers are checked.** In the wire-facing files
//!    (`coordinator/protocol.rs`, `shard/remote.rs`, `shard/serde.rs`,
//!    `util/json.rs`) every `as` numeric cast is either replaced with
//!    `try_from` surfacing a descriptive error, or carries a `// CAST:`
//!    comment proving losslessness (widening, bounds-checked, or
//!    explicitly tolerated rounding in latency reports).
//!
//! 5. **The hot path does not panic.** In the serve-path files
//!    (reactor, conn, sys, pool, shard/remote) `panic!` / `unwrap` /
//!    `expect` require a `// PANIC:` justification — allowed only for
//!    construction-time setup, mutex poison (a prior panic already
//!    tearing the process down), and stated invariants.
//!
//! 6. **The epoch plane is schedule-checked.** The RCU counter-plane
//!    protocol behind live updates is exercised by
//!    [`crate::audit::interleave`]: every feasible two-thread
//!    interleaving (plus seeded three-thread walks) must leave pinned
//!    snapshots bitwise identical to a single-pass rebuild.  The
//!    battery runs in `cargo test` and in `tests/audit_interleave.rs`.

pub mod conn;
pub mod reactor;
pub mod sys;

pub use reactor::{CompletionSender, LineHandler, Reactor};
