//! Per-connection state for the reactor: incremental line framing with
//! a hard length cap, buffered nonblocking writes, and in-flight
//! accounting for deferred close.
//!
//! The cap is the OOM fix: the seed buffered an entire line in
//! `BufRead::lines`, so a newline-free stream grew the heap without
//! bound.  Here a line that exceeds [`MAX_LINE_BYTES`] is answered with
//! an error (id recovered best-effort from the kept prefix) and the
//! rest of the oversize line is *discarded* as it streams in — memory
//! stays bounded and the connection survives for subsequent requests.

use std::io::{Read, Write};
use std::net::TcpStream;

/// Hard cap on a single request line (bytes, excluding the newline).
pub const MAX_LINE_BYTES: usize = 256 * 1024;

/// Prefix of an oversize line kept for best-effort id extraction.
pub const OVERSIZE_PREFIX_BYTES: usize = 4 * 1024;

/// Cap on buffered-but-unsent response bytes.  A client that pipelines
/// requests without ever reading responses is disconnected rather than
/// allowed to grow the heap.
pub const MAX_WRITE_BUF_BYTES: usize = 16 * 1024 * 1024;

/// One framed input event.
pub enum InEvent {
    /// A complete request line (without the trailing newline).
    Line(String),
    /// The line cap fired; the payload is the kept prefix for
    /// best-effort id extraction.  The rest of the line is discarded
    /// as it arrives.
    Oversize(String),
}

pub struct Conn {
    pub stream: TcpStream,
    /// Partial input line (bytes since the last `\n`).
    rbuf: Vec<u8>,
    /// Serialized responses not yet accepted by the socket.
    wbuf: Vec<u8>,
    /// Bytes of `wbuf` already written.
    wpos: usize,
    /// Inside an oversize line: drop bytes until the next `\n`.
    discarding: bool,
    /// Requests submitted to the router whose responses have not yet
    /// been queued into `wbuf`.
    pub in_flight: usize,
    /// Peer finished sending (EOF seen); close once fully drained.
    pub read_closed: bool,
    /// Interest bits currently registered with epoll.
    pub interest: u32,
}

impl Conn {
    pub fn new(stream: TcpStream) -> Conn {
        Conn {
            stream,
            rbuf: Vec::new(),
            wbuf: Vec::new(),
            wpos: 0,
            discarding: false,
            in_flight: 0,
            read_closed: false,
            interest: 0,
        }
    }

    /// Read what the socket has, appending framed events to `out`.
    /// Returns `false` when the connection is broken and must be torn
    /// down immediately; EOF instead sets `read_closed` so pending
    /// responses still drain.
    ///
    /// Reads are bounded per call: a client writing faster than one
    /// scratch-buffer drain per loop would otherwise keep `Ok(n)`
    /// coming forever and head-of-line block every other connection on
    /// the reactor.  Level-triggered epoll re-delivers readiness, so
    /// leftover bytes are picked up on the next event.
    pub fn fill(&mut self, scratch: &mut [u8], out: &mut Vec<InEvent>) -> bool {
        const MAX_READS_PER_EVENT: usize = 16;
        for _ in 0..MAX_READS_PER_EVENT {
            match self.stream.read(scratch) {
                Ok(0) => {
                    self.read_closed = true;
                    if !self.rbuf.is_empty() && !self.discarding {
                        // Final unterminated line — parity with the
                        // legacy BufRead::lines behavior.
                        let line =
                            String::from_utf8_lossy(&self.rbuf).into_owned();
                        self.rbuf.clear();
                        out.push(InEvent::Line(line));
                    }
                    return true;
                }
                Ok(n) => self.frame(&scratch[..n], out),
                Err(ref e)
                    if e.kind() == std::io::ErrorKind::WouldBlock =>
                {
                    return true;
                }
                Err(ref e)
                    if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => return false,
            }
        }
        true
    }

    /// Split a freshly read chunk into lines, honoring discard mode and
    /// the line cap.
    fn frame(&mut self, mut chunk: &[u8], out: &mut Vec<InEvent>) {
        while !chunk.is_empty() {
            if self.discarding {
                match chunk.iter().position(|&b| b == b'\n') {
                    Some(pos) => {
                        self.discarding = false;
                        chunk = &chunk[pos + 1..];
                    }
                    None => return, // whole chunk is oversize spill
                }
                continue;
            }
            match chunk.iter().position(|&b| b == b'\n') {
                Some(pos) => {
                    if self.rbuf.len() + pos > MAX_LINE_BYTES {
                        self.reject_oversize(&chunk[..pos], out);
                        self.discarding = false; // newline is right here
                    } else {
                        let line = if self.rbuf.is_empty() {
                            String::from_utf8_lossy(&chunk[..pos]).into_owned()
                        } else {
                            self.rbuf.extend_from_slice(&chunk[..pos]);
                            let l = String::from_utf8_lossy(&self.rbuf)
                                .into_owned();
                            self.rbuf.clear();
                            l
                        };
                        out.push(InEvent::Line(line));
                    }
                    chunk = &chunk[pos + 1..];
                }
                None => {
                    if self.rbuf.len() + chunk.len() > MAX_LINE_BYTES {
                        self.reject_oversize(chunk, out);
                        self.discarding = true;
                    } else {
                        self.rbuf.extend_from_slice(chunk);
                    }
                    return;
                }
            }
        }
    }

    /// Emit the oversize marker (keeping a prefix for id recovery) and
    /// release the partial-line buffer.
    fn reject_oversize(&mut self, tail: &[u8], out: &mut Vec<InEvent>) {
        let keep = OVERSIZE_PREFIX_BYTES.min(self.rbuf.len());
        let mut prefix = self.rbuf[..keep].to_vec();
        let room = OVERSIZE_PREFIX_BYTES - prefix.len();
        prefix.extend_from_slice(&tail[..room.min(tail.len())]);
        self.rbuf = Vec::new(); // free, don't just clear
        out.push(InEvent::Oversize(
            String::from_utf8_lossy(&prefix).into_owned(),
        ));
    }

    /// Queue one serialized line (newline appended here) for writing.
    /// Line-protocol-agnostic: the inference plane queues `Response`
    /// lines, the shard plane queues shard-message lines, and the
    /// remote-shard *client* reuses this same path for outbound
    /// requests.
    pub fn queue_line(&mut self, line: &str) {
        self.wbuf.reserve(line.len() + 1);
        self.wbuf.extend_from_slice(line.as_bytes());
        self.wbuf.push(b'\n');
    }

    /// Unwritten response bytes.
    pub fn write_backlog(&self) -> usize {
        self.wbuf.len() - self.wpos
    }

    pub fn over_write_cap(&self) -> bool {
        self.write_backlog() > MAX_WRITE_BUF_BYTES
    }

    /// Flush as much of the write buffer as the socket accepts.
    /// `Ok(true)` means fully flushed; `Err` means the connection is
    /// broken.
    pub fn flush(&mut self) -> std::io::Result<bool> {
        while self.wpos < self.wbuf.len() {
            match self.stream.write(&self.wbuf[self.wpos..]) {
                Ok(0) => {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::WriteZero,
                        "socket accepted zero bytes",
                    ));
                }
                Ok(n) => self.wpos += n,
                Err(ref e)
                    if e.kind() == std::io::ErrorKind::WouldBlock =>
                {
                    // Reclaim the flushed prefix so the buffer cannot
                    // creep upward across partial flushes.
                    if self.wpos > 0 {
                        self.wbuf.drain(..self.wpos);
                        self.wpos = 0;
                    }
                    return Ok(false);
                }
                Err(ref e)
                    if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e),
            }
        }
        self.wbuf.clear();
        self.wpos = 0;
        Ok(true)
    }

    /// The connection has nothing left to do and can be dropped.
    pub fn finished(&self) -> bool {
        self.read_closed && self.in_flight == 0 && self.write_backlog() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;
    use std::net::{TcpListener, TcpStream};

    /// Loopback pair: (client stream, server-side Conn, nonblocking).
    fn pair() -> (TcpStream, Conn) {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = l.accept().unwrap();
        server.set_nonblocking(true).unwrap();
        (client, Conn::new(server))
    }

    fn lines(events: &[InEvent]) -> Vec<&str> {
        events
            .iter()
            .filter_map(|e| match e {
                InEvent::Line(l) => Some(l.as_str()),
                InEvent::Oversize(_) => None,
            })
            .collect()
    }

    #[test]
    fn frames_split_lines_across_reads() {
        let (mut client, mut conn) = pair();
        let mut scratch = vec![0u8; 4096];
        let mut out = Vec::new();
        client.write_all(b"hel").unwrap();
        client.flush().unwrap();
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(conn.fill(&mut scratch, &mut out));
        assert!(out.is_empty());
        client.write_all(b"lo\nwor").unwrap();
        client.flush().unwrap();
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(conn.fill(&mut scratch, &mut out));
        assert_eq!(lines(&out), vec!["hello"]);
        client.write_all(b"ld\n\nx\n").unwrap();
        client.flush().unwrap();
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(conn.fill(&mut scratch, &mut out));
        assert_eq!(lines(&out), vec!["hello", "world", "", "x"]);
    }

    #[test]
    fn oversize_line_capped_and_discarded_memory_bounded() {
        let (mut client, mut conn) = pair();
        let mut scratch = vec![0u8; 64 * 1024];
        let mut out = Vec::new();
        // Stream 4 MB without a newline; the cap must fire once and the
        // partial-line buffer must never hold more than the cap.
        let chunk = vec![b'a'; 64 * 1024];
        for _ in 0..64 {
            client.write_all(&chunk).unwrap();
            std::thread::sleep(std::time::Duration::from_millis(1));
            assert!(conn.fill(&mut scratch, &mut out));
            assert!(conn.rbuf.len() <= MAX_LINE_BYTES + 1);
        }
        let n_oversize = out
            .iter()
            .filter(|e| matches!(e, InEvent::Oversize(_)))
            .count();
        assert_eq!(n_oversize, 1);
        assert!(lines(&out).is_empty());
        // End the bad line; the connection keeps framing fresh lines.
        client.write_all(b"\nnext\n").unwrap();
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(conn.fill(&mut scratch, &mut out));
        assert_eq!(lines(&out), vec!["next"]);
    }

    #[test]
    fn eof_flushes_final_unterminated_line() {
        let (mut client, mut conn) = pair();
        let mut scratch = vec![0u8; 4096];
        let mut out = Vec::new();
        client.write_all(b"tail-no-newline").unwrap();
        drop(client);
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(conn.fill(&mut scratch, &mut out));
        assert!(conn.read_closed);
        assert_eq!(lines(&out), vec!["tail-no-newline"]);
    }
}
