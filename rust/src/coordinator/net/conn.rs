//! Per-connection state for the reactor: incremental framing (JSON
//! lines or length-prefixed binary frames), buffered nonblocking
//! writes, and in-flight accounting for deferred close.
//!
//! The line cap is the OOM fix: the seed buffered an entire line in
//! `BufRead::lines`, so a newline-free stream grew the heap without
//! bound.  Here a line that exceeds [`MAX_LINE_BYTES`] is discarded as
//! it streams in — memory stays bounded and the connection survives —
//! while a bounded streaming matcher ([`IdScan`]) recovers the request
//! id from the discarded bytes, wherever it sits in the line, so the
//! error answer still correlates (the old kept-prefix approach lost
//! the id whenever a big `"x"` array preceded it).
//!
//! The binary frame mode is the same bounded-read discipline for the
//! shard plane's length-prefixed protocol (see [`super::frame`]): the
//! declared payload length is validated against a configurable cap
//! before any payload byte is buffered, over-cap frames are discarded
//! byte-exactly with the connection surviving, and a corrupt header
//! (bad magic/version/reserved) is a terminal [`InEvent::FrameError`]
//! because a byte stream cannot be resynchronized past a bad length
//! prefix.  [`WireMode::Auto`] sniffs the first byte of a connection:
//! binary frames start with `b'R'` (`"RSBF"`), JSON lines never do
//! (`{`, digits, or whitespace), so one listening port serves both.

use std::io::{Read, Write};
use std::net::TcpStream;

use super::frame::{self, Frame, HEADER_BYTES, MAX_FRAME_PAYLOAD_BYTES};

/// Hard cap on a single request line (bytes, excluding the newline).
pub const MAX_LINE_BYTES: usize = 256 * 1024;

/// Cap on buffered-but-unsent response bytes.  A client that pipelines
/// requests without ever reading responses is disconnected rather than
/// allowed to grow the heap.  (Default for [`Conn`]'s per-connection
/// `write_cap`, which tests shrink to exercise the refusal path.)
pub const MAX_WRITE_BUF_BYTES: usize = 16 * 1024 * 1024;

/// Which wire protocol a connection speaks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireMode {
    /// Decide per connection by sniffing the first byte: `b'R'` (the
    /// first magic byte of `"RSBF"`) selects binary frames, anything
    /// else selects JSON lines.  Valid JSON never starts with `R`.
    Auto,
    /// Newline-delimited JSON.
    Json,
    /// Length-prefixed binary frames ([`super::frame`]).
    Binary,
}

/// One framed input event.
pub enum InEvent {
    /// A complete request line (without the trailing newline).
    Line(String),
    /// The line cap fired and the whole line has now been discarded.
    /// `id` is the request id recovered by the streaming [`IdScan`]
    /// matcher (`None` when the line carried no parseable `"id"`).
    Oversize { id: Option<u64> },
    /// A complete binary frame (header validated, payload under cap).
    Frame(Frame),
    /// A frame whose declared payload length exceeds the cap.  The
    /// header was valid, so the id correlates; the payload is being
    /// discarded byte-exactly and the connection survives.
    OversizeFrame { verb: u8, id: u64, declared: usize },
    /// A corrupt frame header (bad magic/version/reserved).  Terminal:
    /// the reactor answers descriptively and closes the connection.
    FrameError(String),
}

/// Streaming, constant-memory matcher for `"id": <digits>` inside a
/// discarded oversize line.  Fed every chunk (including across read
/// boundaries); the first complete match wins.  Overflowing digit
/// runs are abandoned rather than wrapped.
#[derive(Clone, Copy, Debug)]
pub struct IdScan {
    found: Option<u64>,
    state: ScanState,
}

#[derive(Clone, Copy, Debug)]
enum ScanState {
    /// Matched this many bytes of the `"id"` needle (0..4).
    Key(u8),
    /// Needle matched; skipping whitespace before the `:`.
    WsColon,
    /// Colon matched; skipping whitespace before the first digit.
    WsDigit,
    /// Accumulating the value.
    Digits(u64),
}

/// On a mismatch, a quote may begin a fresh needle match.
fn rescan(b: u8) -> ScanState {
    if b == b'"' {
        ScanState::Key(1)
    } else {
        ScanState::Key(0)
    }
}

impl IdScan {
    pub fn new() -> IdScan {
        IdScan { found: None, state: ScanState::Key(0) }
    }

    /// Consume one discarded chunk.
    pub fn feed(&mut self, bytes: &[u8]) {
        if self.found.is_some() {
            return;
        }
        const KEY: &[u8; 4] = b"\"id\"";
        for &b in bytes {
            self.state = match self.state {
                ScanState::Key(k) => {
                    if b == KEY[usize::from(k)] {
                        if k == 3 {
                            ScanState::WsColon
                        } else {
                            ScanState::Key(k + 1)
                        }
                    } else {
                        rescan(b)
                    }
                }
                ScanState::WsColon => match b {
                    b' ' | b'\t' | b'\r' => ScanState::WsColon,
                    b':' => ScanState::WsDigit,
                    _ => rescan(b),
                },
                ScanState::WsDigit => match b {
                    b' ' | b'\t' | b'\r' => ScanState::WsDigit,
                    b'0'..=b'9' => ScanState::Digits(u64::from(b - b'0')),
                    _ => rescan(b),
                },
                ScanState::Digits(v) => {
                    if b.is_ascii_digit() {
                        match v
                            .checked_mul(10)
                            .and_then(|x| x.checked_add(u64::from(b - b'0')))
                        {
                            Some(nv) => ScanState::Digits(nv),
                            None => rescan(b), // overflow: not a sane id
                        }
                    } else {
                        self.found = Some(v);
                        return;
                    }
                }
            };
        }
    }

    /// The line ended: a digit run still in flight completes the match.
    pub fn finish(&mut self) -> Option<u64> {
        if self.found.is_none() {
            if let ScanState::Digits(v) = self.state {
                self.found = Some(v);
            }
        }
        self.found
    }
}

/// Per-connection framing state.
enum Framing {
    /// [`WireMode::Auto`] before the first byte arrives.
    Sniff,
    /// JSON line framing.
    Lines,
    /// Inside an oversize line: drop bytes until the next `\n`,
    /// feeding the id matcher as they go.
    LineDiscard(IdScan),
    /// Binary frame framing.
    Frames,
    /// Inside an over-cap frame: drop exactly `remaining` payload
    /// bytes, then resume frame framing.
    FrameDiscard { remaining: usize },
    /// A corrupt frame header was seen: the stream cannot be
    /// resynchronized, so further input is ignored while the one
    /// error answer drains.
    Poisoned,
}

pub struct Conn {
    pub stream: TcpStream,
    /// Partial input (bytes since the last `\n`, or the partial frame).
    rbuf: Vec<u8>,
    /// Serialized responses not yet accepted by the socket.
    wbuf: Vec<u8>,
    /// Bytes of `wbuf` already written.
    wpos: usize,
    /// Framing mode and its in-flight state.
    framing: Framing,
    /// Cap on a single binary frame's declared payload length.
    frame_cap: usize,
    /// Cap on buffered-but-unsent response bytes; also the refusal
    /// threshold for a single response (see `fits_write`).
    write_cap: usize,
    /// Requests submitted to the router whose responses have not yet
    /// been queued into `wbuf`.
    pub in_flight: usize,
    /// Peer finished sending (EOF seen); close once fully drained.
    pub read_closed: bool,
    /// Interest bits currently registered with epoll.
    pub interest: u32,
}

impl Conn {
    /// A JSON-lines connection with default caps (the inference plane).
    pub fn new(stream: TcpStream) -> Conn {
        Conn::new_wire(stream, WireMode::Json, MAX_FRAME_PAYLOAD_BYTES)
    }

    /// A connection in an explicit wire mode with an explicit frame
    /// cap (the shard plane, whose listener defaults to
    /// [`WireMode::Auto`] so one port serves binary and JSON peers).
    pub fn new_wire(stream: TcpStream, wire: WireMode, frame_cap: usize) -> Conn {
        Conn {
            stream,
            rbuf: Vec::new(),
            wbuf: Vec::new(),
            wpos: 0,
            framing: match wire {
                WireMode::Auto => Framing::Sniff,
                WireMode::Json => Framing::Lines,
                WireMode::Binary => Framing::Frames,
            },
            frame_cap,
            write_cap: MAX_WRITE_BUF_BYTES,
            in_flight: 0,
            read_closed: false,
            interest: 0,
        }
    }

    /// Shrink (or grow) the write cap — test-only in practice, but the
    /// reactor threads it from `NetOptions` so the refusal path is
    /// exercisable end-to-end.
    pub fn set_write_cap(&mut self, cap: usize) {
        self.write_cap = cap;
    }

    pub fn write_cap(&self) -> usize {
        self.write_cap
    }

    /// Would a single serialized message of `n` bytes fit under the
    /// write cap at all?  When it cannot, the caller refuses that one
    /// response with a descriptive error instead of queueing bytes
    /// that `over_write_cap` would then punish by tearing the whole
    /// connection down.
    pub fn fits_write(&self, n: usize) -> bool {
        n <= self.write_cap
    }

    /// Read what the socket has, appending framed events to `out`.
    /// Returns `false` when the connection is broken and must be torn
    /// down immediately; EOF instead sets `read_closed` so pending
    /// responses still drain.
    ///
    /// Reads are bounded per call: a client writing faster than one
    /// scratch-buffer drain per loop would otherwise keep `Ok(n)`
    /// coming forever and head-of-line block every other connection on
    /// the reactor.  Level-triggered epoll re-delivers readiness, so
    /// leftover bytes are picked up on the next event.
    pub fn fill(&mut self, scratch: &mut [u8], out: &mut Vec<InEvent>) -> bool {
        const MAX_READS_PER_EVENT: usize = 16;
        for _ in 0..MAX_READS_PER_EVENT {
            match self.stream.read(scratch) {
                Ok(0) => {
                    self.read_closed = true;
                    match &mut self.framing {
                        Framing::Lines if !self.rbuf.is_empty() => {
                            // Final unterminated line — parity with the
                            // legacy BufRead::lines behavior.
                            let line = String::from_utf8_lossy(&self.rbuf)
                                .into_owned();
                            self.rbuf.clear();
                            out.push(InEvent::Line(line));
                        }
                        Framing::LineDiscard(scan) => {
                            // EOF ends the oversize line; surface the
                            // event so the reject still counts even
                            // though no answer can reach the peer.
                            let id = scan.finish();
                            self.framing = Framing::Lines;
                            out.push(InEvent::Oversize { id });
                        }
                        // A partial binary frame at EOF is a mid-frame
                        // disconnect: nobody is left to answer, so the
                        // bytes are dropped and `finished()` reaps the
                        // connection once responses drain.
                        _ => {}
                    }
                    return true;
                }
                Ok(n) => self.frame(&scratch[..n], out),
                Err(ref e)
                    if e.kind() == std::io::ErrorKind::WouldBlock =>
                {
                    return true;
                }
                Err(ref e)
                    if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => return false,
            }
        }
        true
    }

    /// Route a freshly read chunk into the active framing mode,
    /// sniffing it from the first byte when the wire is `Auto`.
    fn frame(&mut self, chunk: &[u8], out: &mut Vec<InEvent>) {
        if chunk.is_empty() {
            return;
        }
        if let Framing::Sniff = self.framing {
            self.framing = if chunk[0] == frame::FRAME_MAGIC[0] {
                Framing::Frames
            } else {
                Framing::Lines
            };
        }
        if matches!(self.framing, Framing::Poisoned) {
            return;
        }
        if matches!(self.framing, Framing::Lines | Framing::LineDiscard(_)) {
            self.frame_lines(chunk, out);
        } else {
            self.frame_frames(chunk, out);
        }
    }

    /// Split a chunk into lines, honoring discard mode and the line
    /// cap.
    fn frame_lines(&mut self, mut chunk: &[u8], out: &mut Vec<InEvent>) {
        while !chunk.is_empty() {
            if let Framing::LineDiscard(scan) = &mut self.framing {
                match chunk.iter().position(|&b| b == b'\n') {
                    Some(pos) => {
                        scan.feed(&chunk[..pos]);
                        let id = scan.finish();
                        self.framing = Framing::Lines;
                        out.push(InEvent::Oversize { id });
                        chunk = &chunk[pos + 1..];
                    }
                    None => {
                        // Whole chunk is oversize spill.
                        scan.feed(chunk);
                        return;
                    }
                }
                continue;
            }
            match chunk.iter().position(|&b| b == b'\n') {
                Some(pos) => {
                    if self.rbuf.len() + pos > MAX_LINE_BYTES {
                        // The newline is right here: scan what we have
                        // and emit the completed oversize event now.
                        let mut scan = self.begin_line_discard();
                        scan.feed(&chunk[..pos]);
                        out.push(InEvent::Oversize { id: scan.finish() });
                        self.framing = Framing::Lines;
                    } else {
                        let line = if self.rbuf.is_empty() {
                            String::from_utf8_lossy(&chunk[..pos]).into_owned()
                        } else {
                            self.rbuf.extend_from_slice(&chunk[..pos]);
                            let l = String::from_utf8_lossy(&self.rbuf)
                                .into_owned();
                            self.rbuf.clear();
                            l
                        };
                        out.push(InEvent::Line(line));
                    }
                    chunk = &chunk[pos + 1..];
                }
                None => {
                    if self.rbuf.len() + chunk.len() > MAX_LINE_BYTES {
                        let mut scan = self.begin_line_discard();
                        scan.feed(chunk);
                        self.framing = Framing::LineDiscard(scan);
                    } else {
                        self.rbuf.extend_from_slice(chunk);
                    }
                    return;
                }
            }
        }
    }

    /// The line cap fired: seed the id matcher with the buffered
    /// prefix and release the partial-line buffer.
    fn begin_line_discard(&mut self) -> IdScan {
        let mut scan = IdScan::new();
        scan.feed(&self.rbuf);
        self.rbuf = Vec::new(); // free, don't just clear
        scan
    }

    /// Incremental binary framing: buffer at most one header plus one
    /// under-cap payload; anything over the cap streams through the
    /// discard state without ever being buffered.
    fn frame_frames(&mut self, mut chunk: &[u8], out: &mut Vec<InEvent>) {
        if let Framing::FrameDiscard { remaining } = self.framing {
            if chunk.len() < remaining {
                self.framing =
                    Framing::FrameDiscard { remaining: remaining - chunk.len() };
                return;
            }
            chunk = &chunk[remaining..];
            self.framing = Framing::Frames;
        }
        self.rbuf.extend_from_slice(chunk);
        while self.rbuf.len() >= HEADER_BYTES {
            let header = match frame::parse_header(&self.rbuf[..HEADER_BYTES]) {
                Ok(h) => h,
                Err(e) => {
                    self.rbuf = Vec::new();
                    self.framing = Framing::Poisoned;
                    out.push(InEvent::FrameError(e));
                    return;
                }
            };
            if header.len > self.frame_cap {
                out.push(InEvent::OversizeFrame {
                    verb: header.verb,
                    id: header.id,
                    declared: header.len,
                });
                let have = self.rbuf.len() - HEADER_BYTES;
                if have >= header.len {
                    self.rbuf.drain(..HEADER_BYTES + header.len);
                    continue;
                }
                self.rbuf = Vec::new(); // free, don't just clear
                self.framing =
                    Framing::FrameDiscard { remaining: header.len - have };
                return;
            }
            if self.rbuf.len() < HEADER_BYTES + header.len {
                return; // wait for the rest of the payload
            }
            let payload =
                self.rbuf[HEADER_BYTES..HEADER_BYTES + header.len].to_vec();
            self.rbuf.drain(..HEADER_BYTES + header.len);
            out.push(InEvent::Frame(Frame {
                verb: header.verb,
                id: header.id,
                payload,
            }));
        }
    }

    /// Queue one serialized line (newline appended here) for writing.
    /// Line-protocol-agnostic: the inference plane queues `Response`
    /// lines, the shard plane queues shard-message lines, and the
    /// remote-shard *client* reuses this same path for outbound
    /// requests.
    pub fn queue_line(&mut self, line: &str) {
        self.wbuf.reserve(line.len() + 1);
        self.wbuf.extend_from_slice(line.as_bytes());
        self.wbuf.push(b'\n');
    }

    /// Queue pre-encoded bytes (a binary frame) for writing — no
    /// delimiter is appended; frames are self-delimiting.
    pub fn queue_bytes(&mut self, bytes: &[u8]) {
        self.wbuf.extend_from_slice(bytes);
    }

    /// Unwritten response bytes.
    pub fn write_backlog(&self) -> usize {
        self.wbuf.len() - self.wpos
    }

    pub fn over_write_cap(&self) -> bool {
        self.write_backlog() > self.write_cap
    }

    /// Flush as much of the write buffer as the socket accepts.
    /// `Ok(true)` means fully flushed; `Err` means the connection is
    /// broken.
    pub fn flush(&mut self) -> std::io::Result<bool> {
        while self.wpos < self.wbuf.len() {
            match self.stream.write(&self.wbuf[self.wpos..]) {
                Ok(0) => {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::WriteZero,
                        "socket accepted zero bytes",
                    ));
                }
                Ok(n) => self.wpos += n,
                Err(ref e)
                    if e.kind() == std::io::ErrorKind::WouldBlock =>
                {
                    // Reclaim the flushed prefix so the buffer cannot
                    // creep upward across partial flushes.
                    if self.wpos > 0 {
                        self.wbuf.drain(..self.wpos);
                        self.wpos = 0;
                    }
                    return Ok(false);
                }
                Err(ref e)
                    if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e),
            }
        }
        self.wbuf.clear();
        self.wpos = 0;
        Ok(true)
    }

    /// The connection has nothing left to do and can be dropped.
    pub fn finished(&self) -> bool {
        self.read_closed && self.in_flight == 0 && self.write_backlog() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;
    use std::net::{TcpListener, TcpStream};

    /// Loopback pair: (client stream, server-side Conn, nonblocking).
    fn pair_wire(wire: WireMode, frame_cap: usize) -> (TcpStream, Conn) {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = l.accept().unwrap();
        server.set_nonblocking(true).unwrap();
        (client, Conn::new_wire(server, wire, frame_cap))
    }

    fn pair() -> (TcpStream, Conn) {
        pair_wire(WireMode::Json, MAX_FRAME_PAYLOAD_BYTES)
    }

    fn lines(events: &[InEvent]) -> Vec<&str> {
        events
            .iter()
            .filter_map(|e| match e {
                InEvent::Line(l) => Some(l.as_str()),
                _ => None,
            })
            .collect()
    }

    fn settle(client: &mut TcpStream, conn: &mut Conn, out: &mut Vec<InEvent>) {
        client.flush().unwrap();
        std::thread::sleep(std::time::Duration::from_millis(20));
        let mut scratch = vec![0u8; 64 * 1024];
        assert!(conn.fill(&mut scratch, out));
    }

    /// Write big payloads in socket-buffer-sized pieces, draining the
    /// server side between pieces so a non-reading loopback peer can
    /// never deadlock `write_all`.
    fn stream_chunks(
        client: &mut TcpStream,
        conn: &mut Conn,
        out: &mut Vec<InEvent>,
        bytes: &[u8],
    ) {
        let mut scratch = vec![0u8; 64 * 1024];
        for piece in bytes.chunks(32 * 1024) {
            client.write_all(piece).unwrap();
            std::thread::sleep(std::time::Duration::from_millis(1));
            assert!(conn.fill(&mut scratch, out));
        }
    }

    #[test]
    fn frames_split_lines_across_reads() {
        let (mut client, mut conn) = pair();
        let mut out = Vec::new();
        client.write_all(b"hel").unwrap();
        settle(&mut client, &mut conn, &mut out);
        assert!(out.is_empty());
        client.write_all(b"lo\nwor").unwrap();
        settle(&mut client, &mut conn, &mut out);
        assert_eq!(lines(&out), vec!["hello"]);
        client.write_all(b"ld\n\nx\n").unwrap();
        settle(&mut client, &mut conn, &mut out);
        assert_eq!(lines(&out), vec!["hello", "world", "", "x"]);
    }

    #[test]
    fn oversize_line_capped_and_discarded_memory_bounded() {
        let (mut client, mut conn) = pair();
        let mut scratch = vec![0u8; 64 * 1024];
        let mut out = Vec::new();
        // Stream 4 MB without a newline; the partial-line buffer must
        // never hold more than the cap, and nothing is emitted until
        // the line actually ends (the id may still be in flight).
        let chunk = vec![b'a'; 64 * 1024];
        for _ in 0..64 {
            client.write_all(&chunk).unwrap();
            std::thread::sleep(std::time::Duration::from_millis(1));
            assert!(conn.fill(&mut scratch, &mut out));
            assert!(conn.rbuf.len() <= MAX_LINE_BYTES + 1);
        }
        assert!(out.is_empty());
        // End the bad line; exactly one oversize event fires and the
        // connection keeps framing fresh lines.
        client.write_all(b"\nnext\n").unwrap();
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(conn.fill(&mut scratch, &mut out));
        let n_oversize = out
            .iter()
            .filter(|e| matches!(e, InEvent::Oversize { .. }))
            .count();
        assert_eq!(n_oversize, 1);
        assert_eq!(lines(&out), vec!["next"]);
    }

    #[test]
    fn oversize_id_recovered_even_when_x_precedes_id() {
        // Regression: the old kept-prefix recovery lost the id when a
        // big "x" array preceded it.  The streaming matcher must find
        // it wherever it lands — including split across reads.
        let (mut client, mut conn) = pair();
        let mut out = Vec::new();
        let big_x = "9.5,".repeat(MAX_LINE_BYTES / 2);
        let head = format!("{{\"op\":\"infer\",\"x\":[{big_x}");
        stream_chunks(&mut client, &mut conn, &mut out, head.as_bytes());
        assert!(out.is_empty());
        // Split the needle itself across two writes.
        client.write_all(b"0.0],\"i").unwrap();
        settle(&mut client, &mut conn, &mut out);
        client.write_all(b"d\" : 7701}\n").unwrap();
        settle(&mut client, &mut conn, &mut out);
        assert_eq!(out.len(), 1);
        match &out[0] {
            InEvent::Oversize { id } => assert_eq!(*id, Some(7701)),
            _ => panic!("expected oversize"),
        }
    }

    #[test]
    fn oversize_id_none_when_line_has_no_id() {
        let (mut client, mut conn) = pair();
        let mut out = Vec::new();
        let junk = vec![b'z'; MAX_LINE_BYTES + 10];
        stream_chunks(&mut client, &mut conn, &mut out, &junk);
        client.write_all(b"\n").unwrap();
        settle(&mut client, &mut conn, &mut out);
        match &out[0] {
            InEvent::Oversize { id } => assert_eq!(*id, None),
            _ => panic!("expected oversize"),
        }
    }

    #[test]
    fn eof_flushes_final_unterminated_line() {
        let (mut client, mut conn) = pair();
        let mut scratch = vec![0u8; 4096];
        let mut out = Vec::new();
        client.write_all(b"tail-no-newline").unwrap();
        drop(client);
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(conn.fill(&mut scratch, &mut out));
        assert!(conn.read_closed);
        assert_eq!(lines(&out), vec!["tail-no-newline"]);
    }

    #[test]
    fn eof_mid_oversize_line_still_reports_the_reject() {
        let (mut client, mut conn) = pair();
        let mut scratch = vec![0u8; 64 * 1024];
        let mut out = Vec::new();
        let mut line = b"{\"id\":42,\"x\":[".to_vec();
        line.extend(vec![b'1'; MAX_LINE_BYTES + 10]);
        stream_chunks(&mut client, &mut conn, &mut out, &line);
        drop(client); // no newline ever arrives
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(conn.fill(&mut scratch, &mut out));
        assert!(conn.read_closed);
        // The digit spill after "x":[ must not clobber the already-
        // matched id=42... it does extend it: "x":[111... has no "id"
        // needle, and the id was matched up front.
        match &out[0] {
            InEvent::Oversize { id } => assert_eq!(*id, Some(42)),
            _ => panic!("expected oversize"),
        }
    }

    #[test]
    fn id_scan_matches_across_arbitrary_chunking() {
        let line = b"{\"x\":[1,2,3],\"note\":\"id 9 \\\"id\\\"\",\"id\":31415926,\"op\":\"infer\"}";
        for chunk in 1..9usize {
            let mut scan = IdScan::new();
            for piece in line.chunks(chunk) {
                scan.feed(piece);
            }
            assert_eq!(scan.finish(), Some(31415926), "chunk={chunk}");
        }
        // First complete match wins.
        let mut scan = IdScan::new();
        scan.feed(b"{\"id\":5}{\"id\":6}");
        assert_eq!(scan.finish(), Some(5));
        // Overflowing digit runs are abandoned, later ids still match.
        let mut scan = IdScan::new();
        scan.feed(b"{\"id\":99999999999999999999999,\"id\":8}");
        assert_eq!(scan.finish(), Some(8));
    }

    #[test]
    fn binary_frames_parse_across_split_reads() {
        let (mut client, mut conn) = pair_wire(WireMode::Binary, 1024);
        let mut out = Vec::new();
        let f1 = frame::encode(2, 11, &[1, 2, 3, 4, 5]);
        let f2 = frame::encode(4, 12, b"");
        // Dribble the first frame byte by byte through the header
        // boundary, then the rest plus the second frame at once.
        client.write_all(&f1[..7]).unwrap();
        settle(&mut client, &mut conn, &mut out);
        assert!(out.is_empty());
        client.write_all(&f1[7..21]).unwrap();
        settle(&mut client, &mut conn, &mut out);
        assert!(out.is_empty());
        client.write_all(&f1[21..]).unwrap();
        client.write_all(&f2).unwrap();
        settle(&mut client, &mut conn, &mut out);
        assert_eq!(out.len(), 2);
        match &out[0] {
            InEvent::Frame(f) => {
                assert_eq!((f.verb, f.id), (2, 11));
                assert_eq!(f.payload, vec![1, 2, 3, 4, 5]);
            }
            _ => panic!("expected frame"),
        }
        match &out[1] {
            InEvent::Frame(f) => {
                assert_eq!((f.verb, f.id), (4, 12));
                assert!(f.payload.is_empty());
            }
            _ => panic!("expected frame"),
        }
    }

    #[test]
    fn over_cap_frame_discarded_byte_exactly_connection_survives() {
        let (mut client, mut conn) = pair_wire(WireMode::Binary, 64);
        let mut scratch = vec![0u8; 4 * 1024];
        let mut out = Vec::new();
        let big = frame::encode(2, 77, &vec![0xAB; 300]);
        let next = frame::encode(3, 78, b"ok");
        client.write_all(&big).unwrap();
        client.write_all(&next).unwrap();
        client.flush().unwrap();
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(conn.fill(&mut scratch, &mut out));
        assert_eq!(out.len(), 2);
        match &out[0] {
            InEvent::OversizeFrame { verb, id, declared } => {
                assert_eq!((*verb, *id, *declared), (2, 77, 300));
            }
            _ => panic!("expected oversize frame"),
        }
        match &out[1] {
            InEvent::Frame(f) => assert_eq!((f.verb, f.id), (3, 78)),
            _ => panic!("expected frame after discard"),
        }
        // And with the payload dribbled so the discard state persists
        // across fills.
        let mut out = Vec::new();
        let big = frame::encode(2, 79, &vec![0xCD; 500]);
        client.write_all(&big[..40]).unwrap();
        settle(&mut client, &mut conn, &mut out);
        client.write_all(&big[40..]).unwrap();
        client.write_all(&frame::encode(4, 80, b"")).unwrap();
        settle(&mut client, &mut conn, &mut out);
        assert!(matches!(
            out[0],
            InEvent::OversizeFrame { verb: 2, id: 79, declared: 500 }
        ));
        assert!(
            matches!(&out[1], InEvent::Frame(f) if f.id == 80),
            "connection must keep framing after a dribbled discard"
        );
    }

    #[test]
    fn corrupt_header_is_a_terminal_frame_error() {
        let (mut client, mut conn) = pair_wire(WireMode::Binary, 1024);
        let mut out = Vec::new();
        client.write_all(b"RSBFxxxxxxxxxxxxxxxxxxxx").unwrap();
        settle(&mut client, &mut conn, &mut out);
        assert_eq!(out.len(), 1);
        match &out[0] {
            InEvent::FrameError(e) => assert!(e.contains("version"), "{e}"),
            _ => panic!("expected frame error"),
        }
    }

    #[test]
    fn auto_wire_sniffs_json_and_binary() {
        let (mut client, mut conn) = pair_wire(WireMode::Auto, 1024);
        let mut out = Vec::new();
        client.write_all(b"{\"id\":1}\n").unwrap();
        settle(&mut client, &mut conn, &mut out);
        assert_eq!(lines(&out), vec!["{\"id\":1}"]);

        let (mut client, mut conn) = pair_wire(WireMode::Auto, 1024);
        let mut out = Vec::new();
        client.write_all(&frame::encode(1, 9, b"hi")).unwrap();
        settle(&mut client, &mut conn, &mut out);
        assert_eq!(out.len(), 1);
        assert!(matches!(&out[0], InEvent::Frame(f) if f.id == 9));

        // A JSON line at a *binary-only* port is a bad-magic error.
        let (mut client, mut conn) = pair_wire(WireMode::Binary, 1024);
        let mut out = Vec::new();
        client.write_all(b"{\"id\":1,\"op\":\"hello\",\"padpadpad\":0}\n").unwrap();
        settle(&mut client, &mut conn, &mut out);
        assert!(matches!(&out[0], InEvent::FrameError(e) if e.contains("magic")));
    }

    #[test]
    fn write_cap_refusal_predicate() {
        let (_client, mut conn) = pair();
        assert!(conn.fits_write(MAX_WRITE_BUF_BYTES));
        assert!(!conn.fits_write(MAX_WRITE_BUF_BYTES + 1));
        conn.set_write_cap(100);
        assert!(conn.fits_write(100));
        assert!(!conn.fits_write(101));
        conn.queue_line(&"y".repeat(200));
        assert!(conn.over_write_cap());
    }
}
