//! Minimal Linux syscall surface for the reactor: epoll, fcntl, pipe,
//! and the SIGINT/SIGTERM → stop-flag bridge for graceful shutdown.
//!
//! Declared directly via `extern "C"` against libc — which every Linux
//! Rust binary already links — because the offline image vendors no
//! registry crates (`libc`/`mio`/`tokio` are unavailable, the same
//! constraint that led to the in-tree `anyhow`).  Only the handful of
//! calls the reactor needs are declared, each behind a safe wrapper
//! that owns its fd.

use std::io;
use std::os::raw::{c_int, c_void};
use std::sync::atomic::{AtomicBool, AtomicPtr, Ordering};
use std::sync::Arc;

pub const EPOLLIN: u32 = 0x001;
pub const EPOLLOUT: u32 = 0x004;
pub const EPOLLERR: u32 = 0x008;
pub const EPOLLHUP: u32 = 0x010;
pub const EPOLLRDHUP: u32 = 0x2000;

const EPOLL_CTL_ADD: c_int = 1;
const EPOLL_CTL_DEL: c_int = 2;
const EPOLL_CTL_MOD: c_int = 3;

const EPOLL_CLOEXEC: c_int = 0o2000000;

const F_GETFL: c_int = 3;
const F_SETFL: c_int = 4;
const O_NONBLOCK: c_int = 0o4000;

/// Kernel `struct epoll_event`.  Packed on x86 so the 64-bit user data
/// sits at offset 4 (the kernel ABI there); naturally aligned on other
/// architectures.  Fields are only ever copied out, never referenced.
#[repr(C)]
#[cfg_attr(any(target_arch = "x86", target_arch = "x86_64"), repr(packed))]
#[derive(Clone, Copy)]
pub struct EpollEvent {
    pub events: u32,
    pub data: u64,
}

extern "C" {
    fn epoll_create1(flags: c_int) -> c_int;
    fn epoll_ctl(
        epfd: c_int,
        op: c_int,
        fd: c_int,
        event: *mut EpollEvent,
    ) -> c_int;
    fn epoll_wait(
        epfd: c_int,
        events: *mut EpollEvent,
        maxevents: c_int,
        timeout: c_int,
    ) -> c_int;
    fn fcntl(fd: c_int, cmd: c_int, arg: c_int) -> c_int;
    fn pipe(fds: *mut c_int) -> c_int;
    fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
    fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
    fn close(fd: c_int) -> c_int;
}

fn cvt(ret: c_int) -> io::Result<c_int> {
    if ret < 0 {
        Err(io::Error::last_os_error())
    } else {
        Ok(ret)
    }
}

/// `fcntl(F_SETFL, flags | O_NONBLOCK)` — used for the wake pipe (std
/// already covers the sockets via `set_nonblocking`).
pub fn set_nonblocking(fd: c_int) -> io::Result<()> {
    // SAFETY: value-only arguments on a caller-owned fd; the kernel
    // validates fd and reports misuse through -1/errno, which cvt maps.
    let flags = cvt(unsafe { fcntl(fd, F_GETFL, 0) })?;
    // SAFETY: same value-only call; result checked through cvt.
    cvt(unsafe { fcntl(fd, F_SETFL, flags | O_NONBLOCK) })?;
    Ok(())
}

/// An owned epoll instance.
pub struct Epoll {
    fd: c_int,
}

impl Epoll {
    pub fn new() -> io::Result<Epoll> {
        // SAFETY: no pointers cross the boundary; the returned fd is
        // owned by the Epoll and closed exactly once in Drop.
        let fd = cvt(unsafe { epoll_create1(EPOLL_CLOEXEC) })?;
        Ok(Epoll { fd })
    }

    pub fn add(&self, fd: c_int, interest: u32, token: u64) -> io::Result<()> {
        self.ctl(EPOLL_CTL_ADD, fd, interest, token)
    }

    pub fn modify(
        &self,
        fd: c_int,
        interest: u32,
        token: u64,
    ) -> io::Result<()> {
        self.ctl(EPOLL_CTL_MOD, fd, interest, token)
    }

    pub fn del(&self, fd: c_int) -> io::Result<()> {
        // A non-null event pointer keeps pre-2.6.9 kernels happy.
        self.ctl(EPOLL_CTL_DEL, fd, 0, 0)
    }

    fn ctl(
        &self,
        op: c_int,
        fd: c_int,
        interest: u32,
        token: u64,
    ) -> io::Result<()> {
        let mut ev = EpollEvent { events: interest, data: token };
        // SAFETY: `ev` is a live stack value for the duration of the
        // call and the kernel only reads it; result checked through cvt.
        cvt(unsafe { epoll_ctl(self.fd, op, fd, &mut ev) })?;
        Ok(())
    }

    /// Wait up to `timeout_ms` (retrying on EINTR); fills `events` and
    /// returns the ready count.
    pub fn wait(
        &self,
        events: &mut [EpollEvent],
        timeout_ms: c_int,
    ) -> io::Result<usize> {
        loop {
            // SAFETY: the out-pointer and its capacity come from the
            // same live slice, so the kernel writes only within bounds;
            // the result is checked below (>=0 count, else errno).
            let n = unsafe {
                epoll_wait(
                    self.fd,
                    events.as_mut_ptr(),
                    events.len() as c_int,
                    timeout_ms,
                )
            };
            if n >= 0 {
                return Ok(n as usize);
            }
            let err = io::Error::last_os_error();
            if err.kind() != io::ErrorKind::Interrupted {
                return Err(err);
            }
        }
    }
}

impl Drop for Epoll {
    fn drop(&mut self) {
        // SAFETY: `self.fd` is the epoll fd this struct owns; nothing
        // else closes it, so this is the single close.
        unsafe {
            // ERRNO: Drop cannot propagate; EBADF is impossible for an
            // owned fd and EINTR on close must not be retried on Linux.
            close(self.fd);
        }
    }
}

/// Self-wake pipe: lane workers write a byte after queueing a finished
/// response; the reactor drains the pipe and collects the completions.
/// Both ends are nonblocking — a full pipe just means a wake is already
/// pending, which is all that matters.
pub struct WakePipe {
    r: c_int,
    w: c_int,
}

impl WakePipe {
    pub fn new() -> io::Result<WakePipe> {
        let mut fds = [0 as c_int; 2];
        // SAFETY: the out-pointer addresses a live 2-element array the
        // kernel fills; result checked through cvt.
        cvt(unsafe { pipe(fds.as_mut_ptr()) })?;
        let (r, w) = (fds[0], fds[1]);
        if let Err(e) = set_nonblocking(r).and_then(|()| set_nonblocking(w)) {
            // SAFETY: both fds were just created by pipe() above and
            // are not yet owned by any WakePipe; closed exactly once.
            unsafe {
                // ERRNO: already on the fcntl error path — the fcntl
                // error is the one to surface, a close failure on a
                // fresh pipe fd carries no extra signal.
                close(r);
                // ERRNO: same as above.
                close(w);
            }
            return Err(e);
        }
        Ok(WakePipe { r, w })
    }

    pub fn read_fd(&self) -> c_int {
        self.r
    }

    /// Poke the reactor.  EAGAIN (pipe full) is ignored: a wake is
    /// already queued.
    pub fn wake(&self) {
        let b = [1u8];
        // SAFETY: the buffer pointer/length name one live byte and the
        // kernel only reads it.
        // ERRNO: the write end is nonblocking, so the only failure mode
        // is EAGAIN on a full pipe — and a full pipe already contains a
        // pending wake byte, which is the entire point of the call.
        let _ = unsafe { write(self.w, b.as_ptr() as *const c_void, 1) };
    }

    /// Drain every pending wake byte.
    pub fn drain(&self) {
        let mut buf = [0u8; 256];
        loop {
            // SAFETY: pointer and length name the same live stack
            // buffer, so the kernel writes only within bounds; the
            // result is checked below (<= 0 terminates the drain).
            let n = unsafe {
                read(self.r, buf.as_mut_ptr() as *mut c_void, buf.len())
            };
            if n <= 0 {
                break;
            }
        }
    }
}

impl Drop for WakePipe {
    fn drop(&mut self) {
        // SAFETY: both fds are owned by this WakePipe and closed
        // exactly once, here.
        unsafe {
            // ERRNO: Drop cannot propagate; EBADF is impossible for an
            // owned fd and EINTR on close must not be retried on Linux.
            close(self.r);
            // ERRNO: same as above.
            close(self.w);
        }
    }
}

pub const SIGINT: c_int = 2;
pub const SIGTERM: c_int = 15;

extern "C" {
    /// `signal(2)` — sufficient here: the handler only flips a flag and
    /// never needs `sigaction`'s mask/flags control, and declaring it
    /// avoids hand-writing the platform-dependent `sigaction` layout.
    fn signal(signum: c_int, handler: usize) -> usize;
}

/// Where the handler stores.  A raw leaked-Arc pointer (not a plain
/// static flag) so each server wires signals to ITS OWN stop handle —
/// the reactor polls exactly that flag every `IDLE_WAIT_MS`.
static STOP_TARGET: AtomicPtr<AtomicBool> =
    AtomicPtr::new(std::ptr::null_mut());

extern "C" fn stop_signal_handler(_sig: c_int) {
    // Async-signal-safe by construction: one atomic load, one atomic
    // store.  No allocation, no locks, no formatting, no IO.
    //
    // ORDERING: Acquire pairs with the Release store in
    // install_stop_signals, so the handler sees a fully initialized
    // AtomicBool behind the pointer it loads.
    let p = STOP_TARGET.load(Ordering::Acquire);
    if !p.is_null() {
        // SAFETY: non-null means install_stop_signals published a
        // pointer from Arc::into_raw that is intentionally never freed
        // (see below), so it outlives every signal delivery.
        // ORDERING: Release pairs with the reactor's Acquire poll of
        // the stop flag in its idle wait.
        unsafe { (*p).store(true, Ordering::Release) };
    }
}

/// Route SIGINT/SIGTERM into `stop`: the first signal flips the flag,
/// the reactor observes it within its idle wait, closes connections,
/// and `serve()` returns — turning `kill` into the same drain path as
/// an orderly shutdown instead of a mid-burst abort.
///
/// The Arc clone is leaked into the handler's static slot (a signal
/// handler outlives every scope; a previously installed target is
/// intentionally leaked too rather than freed under a concurrent
/// signal).  A process installs this once per served socket — the leak
/// is a few bytes, bounded by install count.
pub fn install_stop_signals(stop: &Arc<AtomicBool>) {
    let raw = Arc::into_raw(stop.clone()) as *mut AtomicBool;
    // ORDERING: Release pairs with the handler's Acquire load, making
    // the Arc's heap contents visible before the pointer is.
    STOP_TARGET.store(raw, Ordering::Release);
    // SAFETY: registers a fn-pointer handler that is async-signal-safe
    // (see stop_signal_handler); signum values are valid constants.
    let (r1, r2) = unsafe {
        (
            signal(SIGINT, stop_signal_handler as usize),
            signal(SIGTERM, stop_signal_handler as usize),
        )
    };
    // SIG_ERR is usize::MAX; with valid constant signums it cannot
    // occur, but surface a kernel surprise loudly in debug builds.
    debug_assert!(r1 != usize::MAX && r2 != usize::MAX);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wake_pipe_roundtrip() {
        let p = WakePipe::new().unwrap();
        let ep = Epoll::new().unwrap();
        ep.add(p.read_fd(), EPOLLIN, 7).unwrap();
        let mut evs = [EpollEvent { events: 0, data: 0 }; 8];
        // Nothing pending: times out empty.
        assert_eq!(ep.wait(&mut evs, 0).unwrap(), 0);
        p.wake();
        p.wake();
        let n = ep.wait(&mut evs, 1000).unwrap();
        assert_eq!(n, 1);
        let (events, data) = (evs[0].events, evs[0].data);
        assert_ne!(events & EPOLLIN, 0);
        assert_eq!(data, 7);
        p.drain();
        // Drained: edge back to empty.
        assert_eq!(ep.wait(&mut evs, 0).unwrap(), 0);
    }

    #[test]
    fn stop_signals_flip_the_installed_flag() {
        extern "C" {
            fn raise(sig: c_int) -> c_int;
        }
        let stop = Arc::new(AtomicBool::new(false));
        install_stop_signals(&stop);
        // raise() delivers to the calling thread; the handler only
        // flips the flag, so the test survives its own SIGTERM.
        unsafe { raise(SIGTERM) };
        assert!(stop.load(Ordering::Acquire), "SIGTERM must stop");
        // Re-install onto a fresh flag: SIGINT flips the NEW target.
        let stop2 = Arc::new(AtomicBool::new(false));
        install_stop_signals(&stop2);
        unsafe { raise(SIGINT) };
        assert!(stop2.load(Ordering::Acquire), "SIGINT must stop");
    }

    #[test]
    fn wake_never_blocks_when_full() {
        let p = WakePipe::new().unwrap();
        // A pipe holds ~64KB; hammer well past that — every call must
        // return (nonblocking) rather than deadlock.
        for _ in 0..100_000 {
            p.wake();
        }
        p.drain();
    }
}
