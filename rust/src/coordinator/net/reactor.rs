//! The epoll reactor: ONE thread owns accept, per-connection line
//! framing, request submission, and response write-back.  Lane workers
//! hand finished responses back through an mpsc channel plus a wake
//! pipe — zero per-request or per-connection thread spawns, so the
//! process thread count is fixed at reactor + lane workers + pool no
//! matter how many connections are in flight.
//!
//! The reactor itself is line-protocol-agnostic: every framed line is
//! handed to a [`LineHandler`] together with a [`CompletionSender`],
//! and every completion is a pre-serialized response line.  The
//! inference plane plugs in the `Router` (see the `LineHandler` impl in
//! `coordinator::router`); the shard plane plugs in
//! `shard::remote::ShardService`.  Only the oversize-line rejection is
//! answered in place, because both planes share the `{"id": ...,
//! "error": ...}` error framing and best-effort id recovery.

use super::conn::{Conn, InEvent, MAX_LINE_BYTES};
use super::sys::{
    Epoll, EpollEvent, WakePipe, EPOLLERR, EPOLLHUP, EPOLLIN, EPOLLOUT,
    EPOLLRDHUP,
};
use crate::coordinator::protocol::{extract_id, Response};
use std::collections::HashMap;
use std::net::TcpListener;
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

const TOKEN_LISTENER: u64 = 0;
const TOKEN_WAKE: u64 = 1;
const FIRST_CONN_TOKEN: u64 = 2;

/// How long `epoll_wait` sleeps with nothing ready — bounds how fast an
/// otherwise-idle reactor observes the stop flag (the seed's
/// thread-per-connection loop never observed it from an idle
/// connection at all).
const IDLE_WAIT_MS: i32 = 50;

/// A line-protocol service behind the reactor.
///
/// Contract: for EVERY call, exactly one line must eventually reach the
/// provided [`CompletionSender`] — synchronously (parse errors) or
/// asynchronously from a worker thread.  Implementations guard the
/// asynchronous path with a drop-armed responder (`batcher::Responder`
/// for the inference plane, `shard::remote`'s line guard for the shard
/// plane) so a panicking or torn-down worker still answers.  The
/// reactor counts one in-flight request per handled line and releases
/// it when the completion arrives; a violated contract leaks the
/// connection's in-flight accounting.
pub trait LineHandler: Send + Sync + 'static {
    fn handle_line(&self, line: String, sender: CompletionSender);
}

/// One completed request's way home: tags the serialized response line
/// with the owning connection's token and pokes the reactor awake.
/// Consumed exactly once (see [`LineHandler`]); replaces the seed's one
/// forwarder thread per in-flight request.  Serialization happens on
/// the sending (worker) thread, keeping the reactor thread out of the
/// JSON hot path.
pub struct CompletionSender {
    token: u64,
    tx: Sender<(u64, String)>,
    wake: Arc<WakePipe>,
}

impl CompletionSender {
    /// Deliver an inference-plane [`Response`].
    pub fn send(self, resp: Response) {
        self.send_line(resp.to_line());
    }

    /// Deliver an already-serialized response line (no newline).
    pub fn send_line(self, line: String) {
        let _ = self.tx.send((self.token, line));
        self.wake.wake();
    }
}

pub struct Reactor {
    epoll: Epoll,
    listener: TcpListener,
    wake: Arc<WakePipe>,
    comp_tx: Sender<(u64, String)>,
    comp_rx: Receiver<(u64, String)>,
    conns: HashMap<u64, Conn>,
    next_token: u64,
    handler: Arc<dyn LineHandler>,
    stop: Arc<AtomicBool>,
    accepted: Arc<AtomicU64>,
    scratch: Vec<u8>,
}

impl Reactor {
    pub fn new(
        handler: Arc<dyn LineHandler>,
        listener: &TcpListener,
        stop: Arc<AtomicBool>,
        accepted: Arc<AtomicU64>,
    ) -> std::io::Result<Reactor> {
        let listener = listener.try_clone()?;
        listener.set_nonblocking(true)?;
        let epoll = Epoll::new()?;
        let wake = Arc::new(WakePipe::new()?);
        epoll.add(listener.as_raw_fd(), EPOLLIN, TOKEN_LISTENER)?;
        epoll.add(wake.read_fd(), EPOLLIN, TOKEN_WAKE)?;
        let (comp_tx, comp_rx) = channel();
        Ok(Reactor {
            epoll,
            listener,
            wake,
            comp_tx,
            comp_rx,
            conns: HashMap::new(),
            next_token: FIRST_CONN_TOKEN,
            handler,
            stop,
            accepted,
            scratch: vec![0u8; 64 * 1024],
        })
    }

    /// Event loop; returns when the stop flag flips (observed within
    /// `IDLE_WAIT_MS` even when every connection is idle).  Dropping
    /// the reactor closes all connections.
    pub fn run(&mut self) {
        let mut events = [EpollEvent { events: 0, data: 0 }; 128];
        // ORDERING: Acquire pairs with the Release store in the signal
        // handler / Server::shutdown, so a stop request is observed
        // together with everything written before it.
        while !self.stop.load(Ordering::Acquire) {
            let n = match self.epoll.wait(&mut events, IDLE_WAIT_MS) {
                Ok(n) => n,
                Err(_) => break,
            };
            for ev in &events[..n] {
                let (bits, token) = (ev.events, ev.data);
                match token {
                    TOKEN_LISTENER => self.accept_ready(),
                    TOKEN_WAKE => self.drain_completions(),
                    t => self.conn_ready(t, bits),
                }
            }
        }
    }

    /// Accept until the listener runs dry (level-triggered, so a break
    /// on a transient error just retries on the next readiness).
    fn accept_ready(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    stream.set_nodelay(true).ok();
                    let token = self.next_token;
                    self.next_token += 1;
                    let interest = EPOLLIN | EPOLLRDHUP;
                    if self
                        .epoll
                        .add(stream.as_raw_fd(), interest, token)
                        .is_err()
                    {
                        continue;
                    }
                    let mut conn = Conn::new(stream);
                    conn.interest = interest;
                    self.conns.insert(token, conn);
                    // ORDERING: Relaxed — monotonic stat counter.
                    self.accepted.fetch_add(1, Ordering::Relaxed);
                }
                Err(ref e)
                    if e.kind() == std::io::ErrorKind::WouldBlock =>
                {
                    break;
                }
                Err(ref e)
                    if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => {
                    // EMFILE/ENFILE and friends leave the pending
                    // connection in the backlog, so the level-triggered
                    // listener stays readable — back off briefly
                    // instead of busy-spinning accept at 100% CPU
                    // until an fd frees up.
                    std::thread::sleep(
                        std::time::Duration::from_millis(10),
                    );
                    break;
                }
            }
        }
    }

    /// Route every completed response line back to its connection.  All
    /// pending completions are queued first and each touched
    /// connection is settled once, so a pipelined burst coalesces into
    /// one flush per connection instead of one write(2) per response.
    fn drain_completions(&mut self) {
        self.wake.drain();
        let mut touched: Vec<u64> = Vec::new();
        while let Ok((token, line)) = self.comp_rx.try_recv() {
            if let Some(conn) = self.conns.get_mut(&token) {
                conn.in_flight -= 1;
                conn.queue_line(&line);
                if !touched.contains(&token) {
                    touched.push(token);
                }
            }
            // else: the connection died first; the response is dropped,
            // exactly like a disconnected client under the legacy loop.
        }
        for token in touched {
            self.settle(token);
        }
    }

    /// Socket readiness for one connection.
    fn conn_ready(&mut self, token: u64, bits: u32) {
        if bits & (EPOLLERR | EPOLLHUP) != 0 {
            self.drop_conn(token);
            return;
        }
        if bits & (EPOLLIN | EPOLLRDHUP) != 0 {
            let mut events = Vec::new();
            let ok = match self.conns.get_mut(&token) {
                None => return,
                Some(conn) => conn.fill(&mut self.scratch, &mut events),
            };
            if !ok {
                self.drop_conn(token);
                return;
            }
            for ev in events {
                self.handle_in_event(token, ev);
            }
        }
        self.settle(token);
    }

    /// One framed input line (or an oversize rejection) from a
    /// connection.  Every non-blank line goes to the handler, which
    /// owes the connection exactly one completion; only the oversize
    /// rejection is answered in place (the line never existed as far as
    /// the handler is concerned).
    fn handle_in_event(&mut self, token: u64, ev: InEvent) {
        match ev {
            InEvent::Line(line) => {
                if line.trim().is_empty() {
                    return;
                }
                if let Some(conn) = self.conns.get_mut(&token) {
                    conn.in_flight += 1;
                } else {
                    return;
                }
                self.handler.handle_line(
                    line,
                    CompletionSender {
                        token,
                        tx: self.comp_tx.clone(),
                        wake: self.wake.clone(),
                    },
                );
            }
            InEvent::Oversize(prefix) => {
                if let Some(conn) = self.conns.get_mut(&token) {
                    conn.queue_line(
                        &Response::err(
                            extract_id(&prefix),
                            format!(
                                "bad request: line exceeds the \
                                 {MAX_LINE_BYTES} byte cap"
                            ),
                        )
                        .to_line(),
                    );
                }
            }
        }
    }

    /// Flush what the socket will take, refresh epoll interest, and
    /// close the connection once it is finished (or broken, or abusing
    /// the write buffer).
    fn settle(&mut self, token: u64) {
        let drop_it = match self.conns.get_mut(&token) {
            None => return,
            Some(conn) => match conn.flush() {
                Err(_) => true,
                Ok(_) => {
                    if conn.over_write_cap() || conn.finished() {
                        true
                    } else {
                        let mut want = EPOLLRDHUP;
                        if !conn.read_closed {
                            want |= EPOLLIN;
                        }
                        if conn.write_backlog() > 0 {
                            want |= EPOLLOUT;
                        }
                        if want != conn.interest {
                            let fd = conn.stream.as_raw_fd();
                            match self.epoll.modify(fd, want, token) {
                                Ok(()) => {
                                    conn.interest = want;
                                    false
                                }
                                Err(_) => true,
                            }
                        } else {
                            false
                        }
                    }
                }
            },
        };
        if drop_it {
            self.drop_conn(token);
        }
    }

    fn drop_conn(&mut self, token: u64) {
        if let Some(conn) = self.conns.remove(&token) {
            let _ = self.epoll.del(conn.stream.as_raw_fd());
            // Dropping the stream closes the socket; completions still
            // in flight for this token are discarded on arrival.
        }
    }
}
