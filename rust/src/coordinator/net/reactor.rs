//! The epoll reactor: ONE thread owns accept, per-connection framing
//! (JSON lines and/or binary frames), request submission, and response
//! write-back.  Lane workers hand finished responses back through an
//! mpsc channel plus a wake pipe — zero per-request or per-connection
//! thread spawns, so the process thread count is fixed at reactor +
//! lane workers + pool no matter how many connections are in flight.
//!
//! The reactor itself is protocol-agnostic: every framed input is
//! handed to a [`LineHandler`] together with a [`CompletionSender`],
//! and every completion is a pre-serialized response (a line or an
//! encoded frame).  The inference plane plugs in the `Router` (see the
//! `LineHandler` impl in `coordinator::router`); the shard plane plugs
//! in `shard::remote::ShardService`, which also implements the binary
//! `handle_frame` path.  Only protocol-level rejections — oversize
//! lines, over-cap frames, corrupt frame headers, and responses that
//! cannot fit under the write cap — are answered in place.

use super::conn::{Conn, InEvent, WireMode, MAX_LINE_BYTES};
use super::frame::{self, Frame, HEADER_BYTES, MAX_FRAME_PAYLOAD_BYTES};
use super::sys::{
    Epoll, EpollEvent, WakePipe, EPOLLERR, EPOLLHUP, EPOLLIN, EPOLLOUT,
    EPOLLRDHUP,
};
use crate::coordinator::protocol::{extract_id, Response};
use crate::metrics::slo::FrameSlo;
use std::collections::HashMap;
use std::net::TcpListener;
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

const TOKEN_LISTENER: u64 = 0;
const TOKEN_WAKE: u64 = 1;
const FIRST_CONN_TOKEN: u64 = 2;

/// How long `epoll_wait` sleeps with nothing ready — bounds how fast an
/// otherwise-idle reactor observes the stop flag (the seed's
/// thread-per-connection loop never observed it from an idle
/// connection at all).
const IDLE_WAIT_MS: i32 = 50;

/// Reactor-level wire options, threaded from `Server::bind_opts` down
/// to each accepted [`Conn`].
#[derive(Clone)]
pub struct NetOptions {
    /// Framing for accepted connections.  [`WireMode::Auto`] sniffs
    /// per connection so one port serves binary and JSON peers.
    pub wire: WireMode,
    /// Cap on a single binary frame's declared payload length.
    pub frame_cap: usize,
    /// Cap on buffered-but-unsent response bytes per connection; also
    /// the single-response refusal threshold.  Tests shrink it.
    pub write_cap: usize,
    /// Frame/line reject counters, surfaced through service stats.
    pub slo: Arc<FrameSlo>,
}

impl Default for NetOptions {
    fn default() -> NetOptions {
        NetOptions {
            wire: WireMode::Json,
            frame_cap: MAX_FRAME_PAYLOAD_BYTES,
            write_cap: super::conn::MAX_WRITE_BUF_BYTES,
            slo: Arc::new(FrameSlo::new()),
        }
    }
}

/// A service behind the reactor.
///
/// Contract: for EVERY `handle_line`/`handle_frame` call, exactly one
/// completion must eventually reach the provided [`CompletionSender`]
/// — synchronously (parse errors) or asynchronously from a worker
/// thread.  Implementations guard the asynchronous path with a
/// drop-armed responder (`batcher::Responder` for the inference plane,
/// `shard::remote`'s guard for the shard plane) so a panicking or
/// torn-down worker still answers.  The reactor counts one in-flight
/// request per handled input and releases it when the completion
/// arrives; a violated contract leaks the connection's in-flight
/// accounting.
///
/// `handle_frame` has a default implementation that rejects the frame
/// with a descriptive error frame — a line-only service (the inference
/// `Router`) satisfies the contract without knowing frames exist.
pub trait LineHandler: Send + Sync + 'static {
    fn handle_line(&self, line: String, sender: CompletionSender);

    fn handle_frame(&self, frame: Frame, sender: CompletionSender) {
        sender.send_frame(frame::error_frame(
            frame.id,
            "this service does not speak the binary frame protocol",
        ));
    }
}

/// A completed response on its way back to the reactor: already
/// serialized on the worker thread (a line without its newline, or a
/// fully encoded frame).
enum Outbound {
    Line(String),
    Frame(Vec<u8>),
}

/// One completed request's way home: tags the serialized response with
/// the owning connection's token and pokes the reactor awake.
/// Consumed exactly once (see [`LineHandler`]); replaces the seed's one
/// forwarder thread per in-flight request.  Serialization happens on
/// the sending (worker) thread, keeping the reactor thread out of the
/// JSON/frame encode path.
pub struct CompletionSender {
    token: u64,
    tx: Sender<(u64, Outbound)>,
    wake: Arc<WakePipe>,
}

impl CompletionSender {
    /// Deliver an inference-plane [`Response`].
    pub fn send(self, resp: Response) {
        self.send_line(resp.to_line());
    }

    /// Deliver an already-serialized response line (no newline).
    pub fn send_line(self, line: String) {
        let _ = self.tx.send((self.token, Outbound::Line(line)));
        self.wake.wake();
    }

    /// Deliver an already-encoded binary frame (see
    /// [`super::frame::encode`]).
    pub fn send_frame(self, bytes: Vec<u8>) {
        let _ = self.tx.send((self.token, Outbound::Frame(bytes)));
        self.wake.wake();
    }
}

pub struct Reactor {
    epoll: Epoll,
    listener: TcpListener,
    wake: Arc<WakePipe>,
    comp_tx: Sender<(u64, Outbound)>,
    comp_rx: Receiver<(u64, Outbound)>,
    conns: HashMap<u64, Conn>,
    next_token: u64,
    handler: Arc<dyn LineHandler>,
    stop: Arc<AtomicBool>,
    accepted: Arc<AtomicU64>,
    opts: NetOptions,
    scratch: Vec<u8>,
}

impl Reactor {
    pub fn new(
        handler: Arc<dyn LineHandler>,
        listener: &TcpListener,
        stop: Arc<AtomicBool>,
        accepted: Arc<AtomicU64>,
        opts: NetOptions,
    ) -> std::io::Result<Reactor> {
        let listener = listener.try_clone()?;
        listener.set_nonblocking(true)?;
        let epoll = Epoll::new()?;
        let wake = Arc::new(WakePipe::new()?);
        epoll.add(listener.as_raw_fd(), EPOLLIN, TOKEN_LISTENER)?;
        epoll.add(wake.read_fd(), EPOLLIN, TOKEN_WAKE)?;
        let (comp_tx, comp_rx) = channel();
        Ok(Reactor {
            epoll,
            listener,
            wake,
            comp_tx,
            comp_rx,
            conns: HashMap::new(),
            next_token: FIRST_CONN_TOKEN,
            handler,
            stop,
            accepted,
            opts,
            scratch: vec![0u8; 64 * 1024],
        })
    }

    /// Event loop; returns when the stop flag flips (observed within
    /// `IDLE_WAIT_MS` even when every connection is idle).  Dropping
    /// the reactor closes all connections.
    pub fn run(&mut self) {
        let mut events = [EpollEvent { events: 0, data: 0 }; 128];
        // ORDERING: Acquire pairs with the Release store in the signal
        // handler / Server::shutdown, so a stop request is observed
        // together with everything written before it.
        while !self.stop.load(Ordering::Acquire) {
            let n = match self.epoll.wait(&mut events, IDLE_WAIT_MS) {
                Ok(n) => n,
                Err(_) => break,
            };
            for ev in &events[..n] {
                let (bits, token) = (ev.events, ev.data);
                match token {
                    TOKEN_LISTENER => self.accept_ready(),
                    TOKEN_WAKE => self.drain_completions(),
                    t => self.conn_ready(t, bits),
                }
            }
        }
    }

    /// Accept until the listener runs dry (level-triggered, so a break
    /// on a transient error just retries on the next readiness).
    fn accept_ready(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    stream.set_nodelay(true).ok();
                    let token = self.next_token;
                    self.next_token += 1;
                    let interest = EPOLLIN | EPOLLRDHUP;
                    if self
                        .epoll
                        .add(stream.as_raw_fd(), interest, token)
                        .is_err()
                    {
                        continue;
                    }
                    let mut conn = Conn::new_wire(
                        stream,
                        self.opts.wire,
                        self.opts.frame_cap,
                    );
                    conn.set_write_cap(self.opts.write_cap);
                    conn.interest = interest;
                    self.conns.insert(token, conn);
                    // ORDERING: Relaxed — monotonic stat counter.
                    self.accepted.fetch_add(1, Ordering::Relaxed);
                }
                Err(ref e)
                    if e.kind() == std::io::ErrorKind::WouldBlock =>
                {
                    break;
                }
                Err(ref e)
                    if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => {
                    // EMFILE/ENFILE and friends leave the pending
                    // connection in the backlog, so the level-triggered
                    // listener stays readable — back off briefly
                    // instead of busy-spinning accept at 100% CPU
                    // until an fd frees up.
                    std::thread::sleep(
                        std::time::Duration::from_millis(10),
                    );
                    break;
                }
            }
        }
    }

    /// Route every completed response back to its connection.  All
    /// pending completions are queued first and each touched
    /// connection is settled once, so a pipelined burst coalesces into
    /// one flush per connection instead of one write(2) per response.
    ///
    /// A single response that cannot fit under the write cap AT ALL is
    /// refused here with a descriptive per-request error in the same
    /// wire format — queueing it would trip `over_write_cap` and tear
    /// down the whole connection for one outsized answer (the old
    /// behavior, and a bug: the drop-armed responder already
    /// guarantees exactly-one-response, so refusal is safe).
    fn drain_completions(&mut self) {
        self.wake.drain();
        let mut touched: Vec<u64> = Vec::new();
        while let Ok((token, outbound)) = self.comp_rx.try_recv() {
            if let Some(conn) = self.conns.get_mut(&token) {
                conn.in_flight -= 1;
                match outbound {
                    Outbound::Line(line) => {
                        if conn.fits_write(line.len() + 1) {
                            conn.queue_line(&line);
                        } else {
                            self.opts.slo.inc_write_refused();
                            conn.queue_line(
                                &Response::err(
                                    extract_id(&line),
                                    format!(
                                        "response of {} bytes exceeds \
                                         the {} byte write cap",
                                        line.len() + 1,
                                        conn.write_cap()
                                    ),
                                )
                                .to_line(),
                            );
                        }
                    }
                    Outbound::Frame(bytes) => {
                        if conn.fits_write(bytes.len()) {
                            conn.queue_bytes(&bytes);
                        } else {
                            self.opts.slo.inc_write_refused();
                            let id = if bytes.len() >= HEADER_BYTES {
                                frame::parse_header(&bytes[..HEADER_BYTES])
                                    .map(|h| h.id)
                                    .unwrap_or(0)
                            } else {
                                0
                            };
                            conn.queue_bytes(&frame::error_frame(
                                id,
                                &format!(
                                    "response frame of {} bytes exceeds \
                                     the {} byte write cap",
                                    bytes.len(),
                                    conn.write_cap()
                                ),
                            ));
                        }
                    }
                }
                if !touched.contains(&token) {
                    touched.push(token);
                }
            }
            // else: the connection died first; the response is dropped,
            // exactly like a disconnected client under the legacy loop.
        }
        for token in touched {
            self.settle(token);
        }
    }

    /// Socket readiness for one connection.
    fn conn_ready(&mut self, token: u64, bits: u32) {
        if bits & (EPOLLERR | EPOLLHUP) != 0 {
            self.drop_conn(token);
            return;
        }
        if bits & (EPOLLIN | EPOLLRDHUP) != 0 {
            let mut events = Vec::new();
            let ok = match self.conns.get_mut(&token) {
                None => return,
                Some(conn) => conn.fill(&mut self.scratch, &mut events),
            };
            if !ok {
                self.drop_conn(token);
                return;
            }
            for ev in events {
                self.handle_in_event(token, ev);
            }
        }
        self.settle(token);
    }

    /// One framed input (or a protocol-level rejection) from a
    /// connection.  Every non-blank line and every complete frame goes
    /// to the handler, which owes the connection exactly one
    /// completion; rejections are answered in place (the request never
    /// existed as far as the handler is concerned).
    fn handle_in_event(&mut self, token: u64, ev: InEvent) {
        match ev {
            InEvent::Line(line) => {
                if line.trim().is_empty() {
                    return;
                }
                if let Some(conn) = self.conns.get_mut(&token) {
                    conn.in_flight += 1;
                } else {
                    return;
                }
                self.handler.handle_line(
                    line,
                    CompletionSender {
                        token,
                        tx: self.comp_tx.clone(),
                        wake: self.wake.clone(),
                    },
                );
            }
            InEvent::Frame(f) => {
                if let Some(conn) = self.conns.get_mut(&token) {
                    conn.in_flight += 1;
                } else {
                    return;
                }
                self.handler.handle_frame(
                    f,
                    CompletionSender {
                        token,
                        tx: self.comp_tx.clone(),
                        wake: self.wake.clone(),
                    },
                );
            }
            InEvent::Oversize { id } => {
                self.opts.slo.inc_oversize_line();
                if let Some(conn) = self.conns.get_mut(&token) {
                    conn.queue_line(
                        &Response::err(
                            id,
                            format!(
                                "bad request: line exceeds the \
                                 {MAX_LINE_BYTES} byte cap"
                            ),
                        )
                        .to_line(),
                    );
                }
            }
            InEvent::OversizeFrame { verb, id, declared } => {
                self.opts.slo.inc_oversize_frame();
                let cap = self.opts.frame_cap;
                if let Some(conn) = self.conns.get_mut(&token) {
                    conn.queue_bytes(&frame::error_frame(
                        id,
                        &format!(
                            "frame payload of {declared} bytes (verb \
                             {verb}) exceeds the {cap} byte frame cap"
                        ),
                    ));
                }
            }
            InEvent::FrameError(msg) => {
                self.opts.slo.inc_bad_header();
                if let Some(conn) = self.conns.get_mut(&token) {
                    // Answer once, then close: a corrupt header cannot
                    // be resynchronized.  Marking the read side closed
                    // drops EPOLLIN interest; the connection is reaped
                    // as soon as the answer flushes.
                    conn.queue_bytes(&frame::error_frame(
                        0,
                        &format!("bad frame: {msg}"),
                    ));
                    conn.read_closed = true;
                }
            }
        }
    }

    /// Flush what the socket will take, refresh epoll interest, and
    /// close the connection once it is finished (or broken, or abusing
    /// the write buffer).
    fn settle(&mut self, token: u64) {
        let drop_it = match self.conns.get_mut(&token) {
            None => return,
            Some(conn) => match conn.flush() {
                Err(_) => true,
                Ok(_) => {
                    if conn.over_write_cap() || conn.finished() {
                        true
                    } else {
                        let mut want = EPOLLRDHUP;
                        if !conn.read_closed {
                            want |= EPOLLIN;
                        }
                        if conn.write_backlog() > 0 {
                            want |= EPOLLOUT;
                        }
                        if want != conn.interest {
                            let fd = conn.stream.as_raw_fd();
                            match self.epoll.modify(fd, want, token) {
                                Ok(()) => {
                                    conn.interest = want;
                                    false
                                }
                                Err(_) => true,
                            }
                        } else {
                            false
                        }
                    }
                }
            },
        };
        if drop_it {
            self.drop_conn(token);
        }
    }

    fn drop_conn(&mut self, token: u64) {
        if let Some(conn) = self.conns.remove(&token) {
            let _ = self.epoll.del(conn.stream.as_raw_fd());
            // Dropping the stream closes the socket; completions still
            // in flight for this token are discarded on arrival.
        }
    }
}
