//! The length-prefixed binary frame header: the fixed 20-byte prelude
//! every binary-wire message starts with, parsed incrementally by
//! [`super::conn::Conn`]'s frame mode.
//!
//! # Header layout (all integers little-endian)
//!
//! | offset | size | field                                    |
//! |--------|------|------------------------------------------|
//! | 0      | 4    | magic `b"RSBF"`                          |
//! | 4      | 1    | protocol version (currently 1)           |
//! | 5      | 1    | verb (service-defined; 0 = error reply)  |
//! | 6      | 2    | reserved, must be zero                   |
//! | 8      | 8    | request id (u64, echoed in the reply)    |
//! | 16     | 4    | payload byte length (u32)                |
//!
//! The payload follows immediately: raw bytes whose schema is the
//! verb's business (the shard plane ships raw little-endian f32 bits —
//! see `shard::remote`).  The declared length is validated against a
//! configurable cap BEFORE any payload byte is buffered, so a hostile
//! length can never force an allocation; an over-cap frame is answered
//! descriptively and its payload is discarded as it streams in (the
//! connection survives).  A header whose magic, version, or reserved
//! bytes are wrong is unrecoverable — a byte stream cannot be
//! resynchronized past a corrupt length prefix — so the connection is
//! answered once and closed.
//!
//! Verb 0 ([`VERB_ERROR`]) is reserved across every frame service:
//! an error reply whose payload is the UTF-8 message.  Version
//! negotiation does not live here: services negotiate via their
//! `hello` exchange (the shard plane's hello reply carries the same
//! JSON document on both wires), and a peer speaking a future header
//! version is rejected at the header with a descriptive error.

/// The four magic bytes every binary frame starts with.
pub const FRAME_MAGIC: [u8; 4] = *b"RSBF";

/// The one header version this build speaks.
pub const FRAME_VERSION: u8 = 1;

/// Fixed header size in bytes.
pub const HEADER_BYTES: usize = 20;

/// Default cap on a single frame's declared payload length.  Generous
/// next to [`super::conn::MAX_LINE_BYTES`] because raw f32 payloads
/// are the point of the binary wire; still small enough that a
/// hostile declared length cannot balloon the heap (the declared
/// length is checked BEFORE buffering).
pub const MAX_FRAME_PAYLOAD_BYTES: usize = 64 * 1024 * 1024;

/// Verb 0: an error reply (payload = UTF-8 message).  Shared by every
/// frame-speaking service; the shard verbs live in `shard::remote`.
pub const VERB_ERROR: u8 = 0;

/// A parsed frame header.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FrameHeader {
    pub verb: u8,
    pub id: u64,
    /// Declared payload length in bytes.
    pub len: usize,
}

/// One complete inbound frame (header + buffered payload).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Frame {
    pub verb: u8,
    pub id: u64,
    pub payload: Vec<u8>,
}

/// Parse the fixed header.  `Err` is descriptive and terminal: the
/// stream cannot be resynchronized past a corrupt header.
pub fn parse_header(h: &[u8]) -> Result<FrameHeader, String> {
    debug_assert!(h.len() >= HEADER_BYTES);
    if h[..4] != FRAME_MAGIC {
        return Err(format!(
            "bad frame magic {:02x} {:02x} {:02x} {:02x} (want \
             \"RSBF\") — the peer is not speaking the binary frame \
             protocol (a JSON-line peer should use the line wire)",
            h[0], h[1], h[2], h[3]
        ));
    }
    if h[4] != FRAME_VERSION {
        return Err(format!(
            "unsupported frame version {} (this build speaks {})",
            h[4], FRAME_VERSION
        ));
    }
    if h[6] != 0 || h[7] != 0 {
        return Err(format!(
            "reserved frame header bytes are nonzero ({:02x} {:02x})",
            h[6], h[7]
        ));
    }
    let verb = h[5];
    let id = u64::from_le_bytes([
        h[8], h[9], h[10], h[11], h[12], h[13], h[14], h[15],
    ]);
    let len = u32::from_le_bytes([h[16], h[17], h[18], h[19]]);
    let len = usize::try_from(len)
        .map_err(|_| "frame length does not fit usize".to_string())?;
    Ok(FrameHeader { verb, id, len })
}

/// Encode one frame (header + payload), ready for `Conn::queue_bytes`.
///
/// # Panics
///
/// If `payload.len()` exceeds `u32::MAX` — callers validate payload
/// sizes against their frame cap (<= u32::MAX) before encoding.
pub fn encode(verb: u8, id: u64, payload: &[u8]) -> Vec<u8> {
    // PANIC: encode callers cap payloads well below u32::MAX (frame
    // caps are validated before any payload is built); an over-u32
    // payload here is a programming error, not reachable from input.
    let len = u32::try_from(payload.len()).expect("frame payload fits u32");
    let mut out = Vec::with_capacity(HEADER_BYTES + payload.len());
    out.extend_from_slice(&FRAME_MAGIC);
    out.push(FRAME_VERSION);
    out.push(verb);
    out.extend_from_slice(&[0u8; 2]);
    out.extend_from_slice(&id.to_le_bytes());
    out.extend_from_slice(&len.to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// An error reply frame: [`VERB_ERROR`] with a UTF-8 message payload.
pub fn error_frame(id: u64, msg: &str) -> Vec<u8> {
    encode(VERB_ERROR, id, msg.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_roundtrips() {
        let f = encode(3, 0xDEAD_BEEF_0102_0304, b"xyz");
        assert_eq!(f.len(), HEADER_BYTES + 3);
        let h = parse_header(&f[..HEADER_BYTES]).unwrap();
        assert_eq!(h.verb, 3);
        assert_eq!(h.id, 0xDEAD_BEEF_0102_0304);
        assert_eq!(h.len, 3);
        assert_eq!(&f[HEADER_BYTES..], b"xyz");
    }

    #[test]
    fn zero_length_frames_are_legal() {
        let f = encode(1, 7, b"");
        let h = parse_header(&f).unwrap();
        assert_eq!(h.len, 0);
    }

    #[test]
    fn bad_magic_version_and_reserved_are_descriptive() {
        let good = encode(2, 9, b"p");
        let mut b = good.clone();
        b[0] = b'{';
        let e = parse_header(&b[..HEADER_BYTES]).unwrap_err();
        assert!(e.contains("magic") && e.contains("JSON"), "{e}");
        let mut b = good.clone();
        b[4] = 9;
        let e = parse_header(&b[..HEADER_BYTES]).unwrap_err();
        assert!(e.contains("version 9"), "{e}");
        let mut b = good.clone();
        b[6] = 1;
        let e = parse_header(&b[..HEADER_BYTES]).unwrap_err();
        assert!(e.contains("reserved"), "{e}");
    }

    #[test]
    fn error_frame_carries_the_message() {
        let f = error_frame(42, "no such verb");
        let h = parse_header(&f[..HEADER_BYTES]).unwrap();
        assert_eq!(h.verb, VERB_ERROR);
        assert_eq!(h.id, 42);
        assert_eq!(&f[HEADER_BYTES..], b"no such verb");
    }
}
