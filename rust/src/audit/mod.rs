//! `repsketch-audit`: the in-repo, dependency-free static-analysis pass.
//!
//! The serving stack rests on hand-rolled concurrency — an `extern "C"`
//! epoll reactor ([`crate::coordinator::net`]), a lock-free RCU/epoch
//! counter plane ([`crate::sketch::epoch`]), and lock-free SLO
//! accounting ([`crate::metrics::slo`]).  The repo's contract is that
//! the sketch *provably* approximates inference, bit-for-bit across
//! every serving topology; a data race or torn epoch flip silently
//! voids that proof.  This module is the tooling that guards the unsafe
//! surface before it grows again (io_uring, NUMA pinning):
//!
//! * [`lexer`] — a lightweight Rust lexer (no registry crates, matching
//!   the vendored-`anyhow` constraint) so rules match token patterns,
//!   never raw text;
//! * [`rules`] — the machine-checked invariants catalog (SAFETY
//!   comments, extern-"C" confinement, checked syscall results, atomic
//!   ordering justifications, wire-cast hygiene, panic-free hot
//!   threads), with the annotation grammar documented on each rule;
//! * [`interleave`] — a shuttle-lite deterministic interleaving
//!   harness that drives `sketch::epoch::CounterPlane` through
//!   enumerated and seeded thread schedules, asserting every explored
//!   schedule stays bit-identical to a single-pass rebuild and never
//!   observes a torn buffer.
//!
//! The CLI entry point is `cargo run --release --bin repsketch-audit`
//! (see `src/bin/audit.rs`): it walks `rust/src/**`, prints `file:line:
//! [rule] message` findings, and exits non-zero if any rule fires — CI
//! runs it as a hard gate.

pub mod interleave;
pub mod lexer;
pub mod rules;

pub use rules::{audit_file, Finding};

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// Collect every `.rs` file under `dir`, sorted for stable output.
pub fn walk_rs(dir: &Path) -> io::Result<Vec<PathBuf>> {
    let mut out: Vec<PathBuf> = Vec::new();
    let mut stack: Vec<PathBuf> = vec![dir.to_path_buf()];
    while let Some(d) = stack.pop() {
        for entry in fs::read_dir(&d)? {
            let path = entry?.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
                out.push(path);
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Audit every Rust source file under `<repo_root>/rust/src`.  Findings
/// are sorted by file and line.
pub fn audit_tree(repo_root: &Path) -> io::Result<Vec<Finding>> {
    let src_root = repo_root.join("rust").join("src");
    if !src_root.is_dir() {
        return Err(io::Error::new(
            io::ErrorKind::NotFound,
            format!("{} is not a directory", src_root.display()),
        ));
    }
    let mut findings: Vec<Finding> = Vec::new();
    for path in walk_rs(&src_root)? {
        let rel = path
            .strip_prefix(repo_root)
            .unwrap_or(&path)
            .to_string_lossy()
            .replace('\\', "/");
        let src = fs::read_to_string(&path)?;
        findings.extend(audit_file(&rel, &src));
    }
    findings.sort_by(|a, b| (a.file.as_str(), a.line).cmp(&(b.file.as_str(), b.line)));
    Ok(findings)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The audit must pass on its own repository: this is the in-tree
    /// twin of the CI gate, so `cargo test` alone catches a regression
    /// the moment an unannotated site lands.
    #[test]
    fn repo_tree_is_clean() {
        // CARGO_MANIFEST_DIR is <repo>/rust; the tree root is its parent.
        let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
        let root = match manifest.parent() {
            Some(p) => p.to_path_buf(),
            None => return, // detached layout; the CLI gate still covers it
        };
        if !root.join("rust").join("src").is_dir() {
            return;
        }
        let findings = audit_tree(&root).expect("audit walk failed");
        let shown: Vec<String> = findings.iter().map(|f| f.to_string()).collect();
        assert!(
            findings.is_empty(),
            "repsketch-audit found {} violation(s):\n{}",
            findings.len(),
            shown.join("\n")
        );
    }

    #[test]
    fn audit_tree_reports_missing_root() {
        let err = audit_tree(Path::new("/nonexistent/xyzzy")).err();
        assert!(err.is_some());
    }
}
