//! The machine-checked invariants catalog.
//!
//! Every rule matches over the token stream from [`super::lexer`] (never
//! raw text) and is scoped to non-test code: anything under a `#[test]`
//! function or a `#[cfg(test)]` module/impl is exempt.  The annotation
//! syntax each rule accepts is a marker inside a comment **on the same
//! line** as the flagged token **or on the comment line(s) directly
//! above it** (a contiguous run of comment-only lines; the run may end
//! at a code line's trailing comment).
//!
//! | rule id              | invariant                                            | escape annotation |
//! |----------------------|------------------------------------------------------|-------------------|
//! | `safety-comment`     | every `unsafe` carries a safety argument             | `// SAFETY: <why sound>` (required, not an escape) |
//! | `extern-c-confined`  | `extern "C"` only in `coordinator/net/sys.rs`        | none              |
//! | `syscall-checked`    | fallible syscall results are checked, not discarded  | `// ERRNO: <why ignoring is sound>` |
//! | `ordering-annotated` | every atomic `Ordering::*` justifies its ordering    | `// ORDERING: <pairing argument>` (required) |
//! | `seqcst-justified`   | `SeqCst` is a smell here; must claim it is required  | `// ORDERING: seqcst-required <why>` |
//! | `wire-cast`          | no unvetted `as` numeric cast in wire-facing code    | `// CAST: <why lossless/bounded>` |
//! | `hot-panic`          | no `panic!`/`unwrap`/`expect` on reactor/lane threads| `// PANIC: <why unreachable or sound>` |
//!
//! Scopes: `safety-comment`, `extern-c-confined`, and
//! `ordering-annotated`/`seqcst-justified` apply to every file under
//! `rust/src`; `syscall-checked` applies to `coordinator/net/sys.rs`
//! (the only file allowed to declare syscalls); `wire-cast` applies to
//! the wire-facing modules in [`WIRE_FILES`]; `hot-panic` applies to the
//! modules whose non-test code runs on the reactor thread, pool workers,
//! or the remote-shard lane driver ([`HOT_FILES`]).

use super::lexer::{lex, Tok, TokKind};
use std::collections::{BTreeMap, BTreeSet};

/// Modules that serialize/deserialize wire payloads — plus the LSH
/// geometry and quantized-plane kernels, whose index/code casts sit on
/// the accuracy-critical hot path: lossy `as` casts in any of these
/// silently corrupt values, so they must be `try_from` conversions or
/// carry a `// CAST:` losslessness argument.
pub const WIRE_FILES: &[&str] = &[
    "coordinator/protocol.rs",
    "coordinator/net/frame.rs",
    "coordinator/net/conn.rs",
    "shard/remote.rs",
    "shard/serde.rs",
    "util/json.rs",
    "lsh/l2.rs",
    "lsh/srp.rs",
    "sketch/quant.rs",
];

/// Modules whose non-test code executes on the reactor thread, the
/// persistent pool workers, or the remote-shard lane driver.  A panic
/// there kills a thread every request depends on.
pub const HOT_FILES: &[&str] = &[
    "coordinator/net/reactor.rs",
    "coordinator/net/conn.rs",
    "coordinator/net/frame.rs",
    "coordinator/net/sys.rs",
    "coordinator/pool.rs",
    "shard/remote.rs",
];

/// The one file allowed to declare `extern "C"`.
pub const SYS_FILE: &str = "coordinator/net/sys.rs";

/// Fallible syscalls declared in `sys.rs`: their return value encodes
/// errno and must not be silently discarded.
const SYSCALLS: &[&str] = &[
    "epoll_create1",
    "epoll_ctl",
    "epoll_wait",
    "fcntl",
    "pipe",
    "read",
    "write",
    "close",
    "signal",
    "sigaction",
    "raise",
];

const ORDERINGS: &[&str] = &["Relaxed", "Acquire", "Release", "AcqRel", "SeqCst"];

const NUM_TYPES: &[&str] = &[
    "u8", "u16", "u32", "u64", "u128", "usize", "i8", "i16", "i32", "i64",
    "i128", "isize", "f32", "f64",
];

/// One rule violation at a file:line.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    pub file: String,
    pub line: u32,
    pub rule: &'static str,
    pub msg: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// Per-line comment/code index used by the annotation lookups.
struct LineIndex {
    comment_by_line: BTreeMap<u32, String>,
    code_lines: BTreeSet<u32>,
}

impl LineIndex {
    fn build(toks: &[Tok]) -> LineIndex {
        let mut comment_by_line: BTreeMap<u32, String> = BTreeMap::new();
        let mut code_lines: BTreeSet<u32> = BTreeSet::new();
        for t in toks {
            match t.kind {
                TokKind::LineComment | TokKind::BlockComment => {
                    for l in t.line..=t.end_line {
                        let e = comment_by_line.entry(l).or_default();
                        e.push(' ');
                        e.push_str(&t.text);
                    }
                }
                _ => {
                    for l in t.line..=t.end_line {
                        code_lines.insert(l);
                    }
                }
            }
        }
        LineIndex { comment_by_line, code_lines }
    }

    /// The comment text that "covers" `line`: its own trailing comment
    /// plus the contiguous run of comment lines directly above (the run
    /// may terminate at, and include, a code line's trailing comment).
    fn annotation_text(&self, line: u32) -> String {
        let mut out = String::new();
        if let Some(t) = self.comment_by_line.get(&line) {
            out.push_str(t);
        }
        let mut l = line;
        while l > 1 {
            l -= 1;
            match self.comment_by_line.get(&l) {
                Some(t) => {
                    out.push(' ');
                    out.push_str(t);
                    if self.code_lines.contains(&l) {
                        break;
                    }
                }
                None => break,
            }
        }
        out
    }
}

/// Mark every significant token inside `#[test]` / `#[cfg(test)]`
/// bodies.  `sig` holds indices into `toks` of non-comment tokens; the
/// returned mask parallels `sig`.
fn test_mask(toks: &[Tok], sig: &[usize]) -> Vec<bool> {
    let text = |p: usize| -> &str { &toks[sig[p]].text };
    let mut mask = vec![false; sig.len()];
    let mut p = 0usize;
    while p + 1 < sig.len() {
        if !(text(p) == "#" && text(p + 1) == "[") {
            p += 1;
            continue;
        }
        // Scan the attribute body for `test`, rejecting `not(...)`
        // forms so `#[cfg(not(test))]` never masks production code.
        let mut q = p + 2;
        let mut depth = 1i32;
        let mut has_test = false;
        let mut has_not = false;
        while q < sig.len() && depth > 0 {
            let t = text(q);
            if t == "[" {
                depth += 1;
            } else if t == "]" {
                depth -= 1;
            } else if t == "test" {
                has_test = true;
            } else if t == "not" {
                has_not = true;
            }
            q += 1;
        }
        if !(has_test && !has_not) {
            p = q;
            continue;
        }
        // Skip any further attributes between the test attribute and
        // the item it decorates.
        let mut r = q;
        while r + 1 < sig.len() && text(r) == "#" && text(r + 1) == "[" {
            let mut d = 1i32;
            r += 2;
            while r < sig.len() && d > 0 {
                let t = text(r);
                if t == "[" {
                    d += 1;
                } else if t == "]" {
                    d -= 1;
                }
                r += 1;
            }
        }
        // The decorated item's body: first `{` before any `;`.
        let mut body: Option<usize> = None;
        let mut s = r;
        while s < sig.len() {
            let t = text(s);
            if t == "{" {
                body = Some(s);
                break;
            }
            if t == ";" {
                break;
            }
            s += 1;
        }
        let open = match body {
            Some(b) => b,
            None => {
                p = q;
                continue;
            }
        };
        let mut d = 1i32;
        let mut e = open + 1;
        while e < sig.len() && d > 0 {
            let t = text(e);
            if t == "{" {
                d += 1;
            } else if t == "}" {
                d -= 1;
            }
            e += 1;
        }
        for m in p..e {
            mask[m] = true;
        }
        p = q;
    }
    mask
}

fn suffix_match(rel_path: &str, suffixes: &[&str]) -> bool {
    suffixes.iter().any(|s| rel_path.ends_with(s))
}

/// Run every rule over one file.  `rel_path` is the repo-relative path
/// with `/` separators (used for the per-module rule scopes).
pub fn audit_file(rel_path: &str, src: &str) -> Vec<Finding> {
    let toks = lex(src);
    let sig: Vec<usize> = (0..toks.len())
        .filter(|&i| {
            toks[i].kind != TokKind::LineComment && toks[i].kind != TokKind::BlockComment
        })
        .collect();
    let mask = test_mask(&toks, &sig);
    let li = LineIndex::build(&toks);
    let mut out: Vec<Finding> = Vec::new();
    let text = |p: usize| -> &str { &toks[sig[p]].text };
    let kind = |p: usize| -> TokKind { toks[sig[p]].kind };
    let line = |p: usize| -> u32 { toks[sig[p]].line };
    let is_wire = suffix_match(rel_path, WIRE_FILES);
    let is_hot = suffix_match(rel_path, HOT_FILES);
    let is_sys = rel_path.ends_with(SYS_FILE);
    let mut push = |line: u32, rule: &'static str, msg: String| {
        out.push(Finding { file: rel_path.to_string(), line, rule, msg });
    };
    for p in 0..sig.len() {
        let in_test = mask[p];
        // --- safety-comment -------------------------------------------------
        if kind(p) == TokKind::Ident && text(p) == "unsafe" && !in_test {
            let ann = li.annotation_text(line(p));
            if !ann.contains("SAFETY:") {
                push(
                    line(p),
                    "safety-comment",
                    "`unsafe` without a `// SAFETY:` argument on the same \
                     or preceding comment line"
                        .to_string(),
                );
            }
        }
        // --- extern-c-confined ----------------------------------------------
        if kind(p) == TokKind::Ident
            && text(p) == "extern"
            && p + 1 < sig.len()
            && kind(p + 1) == TokKind::Str
            && text(p + 1) == "C"
            && !is_sys
        {
            push(
                line(p),
                "extern-c-confined",
                format!(
                    "`extern \"C\"` is confined to {}; declare the syscall \
                     there behind a safe wrapper",
                    SYS_FILE
                ),
            );
        }
        // --- syscall-checked ------------------------------------------------
        if is_sys
            && !in_test
            && kind(p) == TokKind::Ident
            && SYSCALLS.contains(&text(p))
            && p + 1 < sig.len()
            && text(p + 1) == "("
        {
            let prev = |k: usize| -> Option<&str> {
                if k < 1 || p < k { None } else { Some(text(p - k)) }
            };
            // Skip method calls / path calls / declarations.
            let direct_call = !matches!(prev(1), Some(".") | Some(":") | Some("fn"));
            if direct_call && discards_result(&toks, &sig, p) {
                let ann = li.annotation_text(line(p));
                if !ann.contains("ERRNO:") {
                    push(
                        line(p),
                        "syscall-checked",
                        format!(
                            "result of fallible syscall `{}` is discarded \
                             without an `// ERRNO:` justification",
                            text(p)
                        ),
                    );
                }
            }
        }
        // --- ordering-annotated / seqcst-justified --------------------------
        if kind(p) == TokKind::Ident
            && text(p) == "Ordering"
            && p + 3 < sig.len()
            && text(p + 1) == ":"
            && text(p + 2) == ":"
            && kind(p + 3) == TokKind::Ident
            && ORDERINGS.contains(&text(p + 3))
            && !in_test
        {
            let ann = li.annotation_text(line(p));
            if !ann.contains("ORDERING:") {
                push(
                    line(p),
                    "ordering-annotated",
                    format!(
                        "`Ordering::{}` without an `// ORDERING:` pairing \
                         argument",
                        text(p + 3)
                    ),
                );
            } else if text(p + 3) == "SeqCst" && !ann.contains("seqcst-required") {
                push(
                    line(p),
                    "seqcst-justified",
                    "`Ordering::SeqCst` is a smell in this codebase; \
                     annotate `// ORDERING: seqcst-required <why>` or \
                     downgrade"
                        .to_string(),
                );
            }
        }
        // --- wire-cast ------------------------------------------------------
        if is_wire
            && !in_test
            && kind(p) == TokKind::Ident
            && text(p) == "as"
            && p + 1 < sig.len()
            && kind(p + 1) == TokKind::Ident
            && NUM_TYPES.contains(&text(p + 1))
        {
            let ann = li.annotation_text(line(p));
            if !ann.contains("CAST:") {
                push(
                    line(p),
                    "wire-cast",
                    format!(
                        "`as {}` in wire-facing code: use a checked \
                         `try_from` conversion or justify with `// CAST:`",
                        text(p + 1)
                    ),
                );
            }
        }
        // --- hot-panic ------------------------------------------------------
        if is_hot && !in_test {
            let hit = if kind(p) == TokKind::Ident
                && text(p) == "panic"
                && p + 1 < sig.len()
                && text(p + 1) == "!"
            {
                Some("panic!")
            } else if kind(p) == TokKind::Ident
                && (text(p) == "unwrap" || text(p) == "expect")
                && p >= 1
                && text(p - 1) == "."
                && p + 1 < sig.len()
                && text(p + 1) == "("
            {
                Some(if text(p) == "unwrap" { ".unwrap()" } else { ".expect()" })
            } else {
                None
            };
            if let Some(what) = hit {
                let ann = li.annotation_text(line(p));
                if !ann.contains("PANIC:") {
                    push(
                        line(p),
                        "hot-panic",
                        format!(
                            "{} on a reactor/lane-worker thread: return an \
                             error or justify with `// PANIC:`",
                            what
                        ),
                    );
                }
            }
        }
    }
    out
}

/// Is the call whose callee identifier sits at significant position `p`
/// an expression-statement (or `let _ =` binding) whose result is
/// dropped?  Walks backwards over the `unsafe {` wrapper the call sites
/// in `sys.rs` all share.
fn discards_result(toks: &[Tok], sig: &[usize], p: usize) -> bool {
    let text = |k: usize| -> &str { &toks[sig[k]].text };
    // `let _ = [unsafe {] call(...)`
    let mut k = p as isize - 1;
    if k >= 0 && text(k as usize) == "{" && k >= 1 && text(k as usize - 1) == "unsafe" {
        k -= 2;
    }
    if k >= 2
        && text(k as usize) == "="
        && text(k as usize - 1) == "_"
        && text(k as usize - 2) == "let"
    {
        return true;
    }
    // Statement position: start of file/block or right after `;` / `}`.
    let k = p as isize - 1;
    if k < 0 {
        return true;
    }
    let prev = text(k as usize);
    if prev == ";" || prev == "}" {
        return true;
    }
    if prev == "{" {
        // `unsafe {` used as an *expression* feeds the value somewhere;
        // a bare `{` (or a statement-position `unsafe {`) drops it.
        if k >= 1 && text(k as usize - 1) == "unsafe" {
            if k < 2 {
                return true;
            }
            let t2 = text(k as usize - 2);
            return t2 == ";" || t2 == "{" || t2 == "}";
        }
        return true;
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_hit(path: &str, src: &str) -> Vec<&'static str> {
        audit_file(path, src).into_iter().map(|f| f.rule).collect()
    }

    const ANY: &str = "rust/src/sketch/somefile.rs";

    // --- safety-comment ---------------------------------------------------

    #[test]
    fn unsafe_without_safety_comment_is_flagged() {
        assert_eq!(
            rules_hit(ANY, "fn f() { unsafe { g(); } }"),
            vec!["safety-comment"]
        );
    }

    #[test]
    fn unsafe_with_safety_comment_passes() {
        let src = "fn f() {\n    // SAFETY: g is sound here\n    unsafe { g(); }\n}\n";
        assert!(rules_hit(ANY, src).is_empty());
        let trailing = "fn f() { unsafe { g(); } } // SAFETY: sound\n";
        assert!(rules_hit(ANY, trailing).is_empty());
    }

    #[test]
    fn unsafe_in_string_comment_or_test_code_is_ignored() {
        assert!(rules_hit(ANY, "let s = \"unsafe { }\";").is_empty());
        assert!(rules_hit(ANY, "// unsafe { g(); }\nlet x = 1;").is_empty());
        assert!(rules_hit(ANY, "let s = r#\"unsafe\"#;").is_empty());
        let test_mod = "#[cfg(test)]\nmod tests {\n    fn f() { unsafe { g(); } }\n}\n";
        assert!(rules_hit(ANY, test_mod).is_empty());
        let test_fn = "#[test]\nfn t() { unsafe { g(); } }\n";
        assert!(rules_hit(ANY, test_fn).is_empty());
    }

    #[test]
    fn cfg_not_test_is_still_production_code() {
        let src = "#[cfg(not(test))]\nmod m {\n    fn f() { unsafe { g(); } }\n}\n";
        assert_eq!(rules_hit(ANY, src), vec!["safety-comment"]);
    }

    // --- extern-c-confined ------------------------------------------------

    #[test]
    fn extern_c_outside_sys_is_flagged() {
        let src = "extern \"C\" {\n    fn close(fd: i32) -> i32;\n}\n";
        assert_eq!(rules_hit(ANY, src), vec!["extern-c-confined"]);
        // In sys.rs the same declaration is the sanctioned home.
        assert!(rules_hit("rust/src/coordinator/net/sys.rs", src).is_empty());
        // Mentions in comments/strings never count.
        assert!(rules_hit(ANY, "// extern \"C\" against libc\n").is_empty());
    }

    // --- syscall-checked --------------------------------------------------

    const SYS: &str = "rust/src/coordinator/net/sys.rs";

    #[test]
    fn discarded_syscall_result_is_flagged() {
        let src = "fn f(fd: i32) {\n    // SAFETY: fd is owned\n    unsafe { close(fd); }\n}\n";
        assert_eq!(rules_hit(SYS, src), vec!["syscall-checked"]);
        let let_u = "fn f(w: i32) {\n    // SAFETY: w is owned\n    let _ = unsafe { write(w, p, 1) };\n}\n";
        assert_eq!(rules_hit(SYS, let_u), vec!["syscall-checked"]);
    }

    #[test]
    fn checked_or_justified_syscalls_pass() {
        let cvt = "fn f(fd: i32) -> io::Result<i32> {\n    // SAFETY: fd valid\n    cvt(unsafe { fcntl(fd, F_GETFL, 0) })\n}\n";
        assert!(rules_hit(SYS, cvt).is_empty());
        let bound = "fn f(fd: i32) {\n    // SAFETY: fd valid\n    let n = unsafe { read(fd, b, 1) };\n    if n < 0 { }\n}\n";
        assert!(rules_hit(SYS, bound).is_empty());
        let ann = "fn f(fd: i32) {\n    // SAFETY: fd is owned\n    // ERRNO: double-close is benign in Drop\n    unsafe { close(fd); }\n}\n";
        assert!(rules_hit(SYS, ann).is_empty());
        // The extern declaration itself is not a call site.
        let decl = "extern \"C\" {\n    fn close(fd: i32) -> i32;\n}\n";
        assert!(rules_hit(SYS, decl).is_empty());
    }

    // --- ordering-annotated / seqcst-justified -----------------------------

    #[test]
    fn unannotated_ordering_is_flagged() {
        let src = "fn f(a: &A) { a.x.load(Ordering::Acquire); }\n";
        assert_eq!(rules_hit(ANY, src), vec!["ordering-annotated"]);
    }

    #[test]
    fn annotated_ordering_passes_and_cmp_ordering_is_ignored() {
        let src = "fn f(a: &A) { a.x.load(Ordering::Acquire); // ORDERING: pairs with the Release store in publish\n}\n";
        assert!(rules_hit(ANY, src).is_empty());
        let above = "fn f(a: &A) {\n    // ORDERING: pairs with publish\n    a.x.load(Ordering::Acquire);\n}\n";
        assert!(rules_hit(ANY, above).is_empty());
        let cmp = "fn f(x: u8, y: u8) -> Ordering { if x < y { Ordering::Less } else { Ordering::Greater } }\n";
        assert!(rules_hit(ANY, cmp).is_empty());
    }

    #[test]
    fn seqcst_needs_the_stronger_annotation() {
        let weak = "fn f(a: &A) { a.x.load(Ordering::SeqCst); // ORDERING: global order\n}\n";
        assert_eq!(rules_hit(ANY, weak), vec!["seqcst-justified"]);
        let strong = "fn f(a: &A) { a.x.load(Ordering::SeqCst); // ORDERING: seqcst-required cross-variable fence\n}\n";
        assert!(rules_hit(ANY, strong).is_empty());
        let bare = "fn f(a: &A) { a.x.load(Ordering::SeqCst); }\n";
        assert_eq!(rules_hit(ANY, bare), vec!["ordering-annotated"]);
    }

    // --- wire-cast ---------------------------------------------------------

    #[test]
    fn lossy_cast_in_wire_module_is_flagged() {
        let src = "fn f(y: u64) -> u32 { y as u32 }\n";
        assert_eq!(rules_hit("rust/src/util/json.rs", src), vec!["wire-cast"]);
        // Same code outside the wire surface is not this rule's business.
        assert!(rules_hit(ANY, src).is_empty());
    }

    #[test]
    fn justified_or_non_numeric_casts_pass() {
        let ann = "fn f(y: u8) -> u32 { y as u32 // CAST: u8 -> u32 widens\n}\n";
        assert!(rules_hit("rust/src/util/json.rs", ann).is_empty());
        let import = "use std::io::Read as IoRead;\n";
        assert!(rules_hit("rust/src/util/json.rs", import).is_empty());
        let test_code = "#[cfg(test)]\nmod tests {\n    fn f(y: u64) -> u32 { y as u32 }\n}\n";
        assert!(rules_hit("rust/src/util/json.rs", test_code).is_empty());
    }

    // --- hot-panic ----------------------------------------------------------

    const HOT: &str = "rust/src/coordinator/net/reactor.rs";

    #[test]
    fn panics_on_hot_threads_are_flagged() {
        assert_eq!(
            rules_hit(HOT, "fn f(x: Option<u8>) -> u8 { x.unwrap() }\n"),
            vec!["hot-panic"]
        );
        assert_eq!(
            rules_hit(HOT, "fn f(x: Option<u8>) -> u8 { x.expect(\"boom\") }\n"),
            vec!["hot-panic"]
        );
        assert_eq!(
            rules_hit(HOT, "fn f() { panic!(\"boom\"); }\n"),
            vec!["hot-panic"]
        );
    }

    #[test]
    fn fallbacks_tests_and_justified_panics_pass() {
        assert!(rules_hit(HOT, "fn f(x: Option<u8>) -> u8 { x.unwrap_or(0) }\n").is_empty());
        assert!(rules_hit(HOT, "#[test]\nfn t() { x.unwrap(); }\n").is_empty());
        let ann = "fn f(m: &Mutex<u8>) -> u8 { *m.lock().unwrap() // PANIC: poisoned lock means a worker already panicked\n}\n";
        assert!(rules_hit(HOT, ann).is_empty());
        // Cold modules may unwrap (CLI arg parsing, tests, experiments).
        assert!(rules_hit(ANY, "fn f(x: Option<u8>) -> u8 { x.unwrap() }\n").is_empty());
    }

    // --- fixture corner cases ----------------------------------------------

    #[test]
    fn macro_bodies_and_commented_out_code_do_not_leak() {
        let src = "macro_rules! m {\n    () => {\n        unsafe { g() }\n    };\n}\n";
        // Macro bodies are real code: still must carry SAFETY.
        assert_eq!(rules_hit(ANY, src), vec!["safety-comment"]);
        let commented = "// let n = unsafe { read(fd) };\n// a.load(Ordering::SeqCst);\nfn f() {}\n";
        assert!(rules_hit(SYS, commented).is_empty());
    }

    #[test]
    fn findings_carry_file_and_line() {
        let src = "fn f() {\n    unsafe { g(); }\n}\n";
        let fs = audit_file(ANY, src);
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].file, ANY);
        assert_eq!(fs[0].line, 2);
        assert_eq!(fs[0].rule, "safety-comment");
        let shown = format!("{}", fs[0]);
        assert!(shown.starts_with("rust/src/sketch/somefile.rs:2: [safety-comment]"));
    }
}
