//! Shuttle-lite deterministic interleaving checks for the epoch plane.
//!
//! [`crate::sketch::epoch::CounterPlane`] promises that every reader
//! snapshot is untorn and that a streamed build is bit-identical to a
//! single-pass rebuild, *under any interleaving* of `pin` / `apply` /
//! `publish` across threads.  The unit tests exercise a handful of
//! schedules; this harness explores the schedule space systematically
//! and deterministically, shuttle-style but with API-level granularity:
//!
//! * Each **model thread** runs a [`Script`] of [`Op`]s (pin, read-check,
//!   unpin, apply, publish) on its own OS thread, but only when the
//!   driver hands it a turn — a turnstile, so a schedule is replayed
//!   exactly, every time, from its step sequence alone.
//! * The driver mirrors the plane's protocol in a pure-Rust model
//!   ([`SimState`]) that predicts, per step, whether an op would block
//!   (a publish parked on a pinned reader's grace period, or the writer
//!   mutex held by a parked publish).  Blocking publishes are allowed —
//!   they run to completion asynchronously once the blocking pin drops
//!   — while steps that would deadlock are excluded by construction, so
//!   exploration never hangs and never depends on timing.
//! * Schedules come from exhaustive enumeration (DFS over feasible
//!   interleavings, up to a cap) and from seeded random walks
//!   ([`crate::util::rng::SplitMix64`]), so CI can replay the exact
//!   schedule that found a violation: every error message carries the
//!   offending step sequence, and [`Interleaver::run_schedule`] replays
//!   one schedule verbatim.
//!
//! Per schedule, the harness asserts:
//!
//! 1. every pinned snapshot is **bit-identical** to the model's expected
//!    published state at that epoch (no torn buffer, no lost or
//!    double-applied delta, no misordered replay);
//! 2. the final plane equals the model fold AND a fresh single-pass
//!    rebuild applying the same deltas in the same global arrival
//!    order — the paper-level bit-identity contract;
//! 3. after the final publish both internal buffers agree bitwise
//!    ([`CounterPlane::snapshot_both`]), i.e. the replay queue folded
//!    every delta into the retired buffer exactly once.

use crate::sketch::epoch::{CounterPlane, PlanePin};
use crate::util::rng::SplitMix64;
use std::collections::BTreeSet;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

/// One API-level step of a model thread.
#[derive(Clone, Debug, PartialEq)]
pub enum Op {
    /// Pin the live buffer and hold the guard across subsequent steps.
    Pin,
    /// Assert the held pin still shows the exact published state of its
    /// epoch (bitwise).
    ReadCheck,
    /// Drop the held pin (ending a grace period).
    Unpin,
    /// Apply one weighted delta (`cols` has one column per plane row).
    Apply { cols: Vec<u32>, class: usize, alpha: f32 },
    /// Publish pending deltas; may park on a concurrent reader's pin.
    Publish,
}

/// The per-thread op sequence.
#[derive(Clone, Debug)]
pub struct Script {
    pub ops: Vec<Op>,
}

/// Driver-side mirror of the plane's blocking protocol.  `step` mirrors
/// exactly what the real plane does; `feasible` excludes the two ways a
/// turn could fail to terminate: running an op on a thread parked in
/// `publish`, and taking the writer mutex (apply/publish) while a parked
/// publish holds it.  A publish that parks on *another* thread's pin is
/// feasible — that is the interesting race — and completes when the
/// last blocking pin unpins.
#[derive(Clone, Debug)]
struct SimState {
    /// Epoch each thread's held pin was taken at (None = no pin).
    pins: Vec<Option<u64>>,
    /// Thread currently parked inside `publish`, if any.
    parked: Option<usize>,
    /// The pre-flip epoch that parked publish is waiting to retire.
    parked_pre: u64,
    /// Published epoch (the plane's `epoch()`).
    epoch: u64,
    /// Unpublished delta count.
    pending: usize,
    /// Set by `step` when an unpin just released a parked publish.
    freed: Option<usize>,
}

impl SimState {
    fn new(threads: usize) -> SimState {
        SimState {
            pins: vec![None; threads],
            parked: None,
            parked_pre: 0,
            epoch: 0,
            pending: 0,
            freed: None,
        }
    }

    fn feasible(&self, t: usize, op: &Op) -> bool {
        if self.parked == Some(t) {
            return false; // thread is inside publish; it has no turn
        }
        if self.parked.is_some() {
            // The parked publish holds the writer mutex.
            if matches!(op, Op::Apply { .. } | Op::Publish) {
                return false;
            }
        }
        match op {
            Op::Pin => self.pins[t].is_none(),
            Op::ReadCheck | Op::Unpin => self.pins[t].is_some(),
            Op::Apply { .. } => true,
            // Publishing while holding one's own pin self-deadlocks on
            // the retired buffer; the real code never does it (pins are
            // per-query, publishes happen between queries).
            Op::Publish => self.pins[t].is_none(),
        }
    }

    fn step(&mut self, t: usize, op: &Op) {
        match op {
            Op::Pin => self.pins[t] = Some(self.epoch),
            Op::ReadCheck => {}
            Op::Unpin => {
                self.pins[t] = None;
                if let Some(pt) = self.parked {
                    let still_blocking =
                        self.pins.iter().any(|p| *p == Some(self.parked_pre));
                    if !still_blocking {
                        self.parked = None;
                        self.freed = Some(pt);
                    }
                }
            }
            Op::Apply { .. } => self.pending += 1,
            Op::Publish => {
                if self.pending > 0 {
                    let pre = self.epoch;
                    self.epoch += 1;
                    self.pending = 0;
                    let blocks = self
                        .pins
                        .iter()
                        .enumerate()
                        .any(|(o, p)| o != t && *p == Some(pre));
                    if blocks {
                        self.parked = Some(t);
                        self.parked_pre = pre;
                    }
                }
            }
        }
    }

    fn take_freed(&mut self) -> Option<usize> {
        self.freed.take()
    }
}

enum Cmd {
    Pin,
    ReadCheck { counters: Vec<f32>, alpha: Vec<f32>, epoch: u64 },
    Unpin,
    Apply { cols: Vec<u32>, class: usize, alpha: f32 },
    Publish,
}

enum Done {
    Pinned(u64),
    Count(usize),
    Epoch(u64),
    Ok,
    Fail(String),
}

fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b.iter()).all(|(x, y)| x.to_bits() == y.to_bits())
}

fn worker(
    plane: Arc<CounterPlane>,
    rx: Receiver<Cmd>,
    tx: Sender<(usize, Done)>,
    id: usize,
) {
    let mut held: Option<PlanePin<'_>> = None;
    while let Ok(cmd) = rx.recv() {
        let done = match cmd {
            Cmd::Pin => {
                let pin = plane.pin();
                let e = pin.epoch;
                held = Some(pin);
                Done::Pinned(e)
            }
            Cmd::ReadCheck { counters, alpha, epoch } => match held.as_ref() {
                None => Done::Fail("read-check without a held pin".to_string()),
                Some(pin) => {
                    if pin.epoch != epoch {
                        Done::Fail(format!(
                            "pinned epoch {} but model expected {}",
                            pin.epoch, epoch
                        ))
                    } else if !bits_eq(&pin.counters, &counters) {
                        Done::Fail(format!(
                            "torn counters: snapshot at epoch {} differs \
                             bitwise from the published fold",
                            epoch
                        ))
                    } else if !bits_eq(&pin.alpha_sums, &alpha) {
                        Done::Fail(format!(
                            "torn alpha_sums at epoch {}",
                            epoch
                        ))
                    } else {
                        Done::Ok
                    }
                }
            },
            Cmd::Unpin => {
                held = None;
                Done::Ok
            }
            Cmd::Apply { cols, class, alpha } => {
                Done::Count(plane.apply(&cols, class, alpha))
            }
            Cmd::Publish => Done::Epoch(plane.publish()),
        };
        if tx.send((id, done)).is_err() {
            break;
        }
    }
    // Channel closed: `held` drops here, ending any grace period this
    // thread was extending, so parked publishers always finish.
}

/// Aggregate results over a set of schedules.
#[derive(Clone, Debug, Default)]
pub struct Report {
    /// Distinct schedules executed.
    pub schedules: usize,
    /// Pinned-snapshot bit-identity checks that ran (and passed).
    pub reads_checked: u64,
    /// Epoch publishes across all schedules.
    pub publishes: u64,
    /// Highest final epoch any schedule reached.
    pub max_epoch: u64,
}

struct ScheduleOutcome {
    reads: u64,
    publishes: u64,
    final_epoch: u64,
}

/// The harness: plane geometry plus one script per model thread.
#[derive(Clone, Debug)]
pub struct Interleaver {
    pub rows: usize,
    pub cols: usize,
    pub classes: usize,
    pub scripts: Vec<Script>,
}

const STEP_TIMEOUT: Duration = Duration::from_secs(30);

impl Interleaver {
    /// The standard 2- or 3-thread scenario: one writer (applies with
    /// order-sensitive magnitudes to colliding cells, publishes
    /// mid-stream), one reader (pin/validate/unpin twice), and — with
    /// `threads >= 3` — a mixed thread that applies, reads, and
    /// publishes.  Colliding columns + `1.0` vs `1e-7` magnitudes make
    /// any replay reordering or double-fold visible in the f32 bits.
    pub fn standard(threads: usize) -> Interleaver {
        let writer = Script {
            ops: vec![
                Op::Apply { cols: vec![1, 3], class: 0, alpha: 1.0 },
                Op::Apply { cols: vec![1, 3], class: 0, alpha: 1.0e-7 },
                Op::Publish,
                Op::Apply { cols: vec![1, 3], class: 1, alpha: -1.0 },
                Op::Publish,
            ],
        };
        let reader = Script {
            ops: vec![
                Op::Pin,
                Op::ReadCheck,
                Op::Unpin,
                Op::Pin,
                Op::ReadCheck,
                Op::Unpin,
            ],
        };
        let mixed = Script {
            ops: vec![
                Op::Apply { cols: vec![3, 1], class: 0, alpha: 0.25 },
                Op::Pin,
                Op::ReadCheck,
                Op::Unpin,
                Op::Publish,
            ],
        };
        let mut scripts = vec![writer, reader];
        if threads >= 3 {
            scripts.push(mixed);
        }
        Interleaver { rows: 2, cols: 4, classes: 2, scripts }
    }

    /// Exhaustively enumerate feasible interleavings (DFS order), up to
    /// `cap` complete schedules.
    pub fn enumerate(&self, cap: usize) -> Vec<Vec<usize>> {
        let mut out: Vec<Vec<usize>> = Vec::new();
        let mut prefix: Vec<usize> = Vec::new();
        let sim = SimState::new(self.scripts.len());
        let progress = vec![0usize; self.scripts.len()];
        self.dfs(&sim, &progress, &mut prefix, &mut out, cap);
        out
    }

    fn dfs(
        &self,
        sim: &SimState,
        progress: &[usize],
        prefix: &mut Vec<usize>,
        out: &mut Vec<Vec<usize>>,
        cap: usize,
    ) {
        if out.len() >= cap {
            return;
        }
        let done = (0..self.scripts.len())
            .all(|t| progress[t] >= self.scripts[t].ops.len());
        if done {
            out.push(prefix.clone());
            return;
        }
        for t in 0..self.scripts.len() {
            if progress[t] >= self.scripts[t].ops.len() {
                continue;
            }
            let op = &self.scripts[t].ops[progress[t]];
            if !sim.feasible(t, op) {
                continue;
            }
            let mut s2 = sim.clone();
            s2.step(t, op);
            s2.take_freed();
            let mut p2 = progress.to_vec();
            p2[t] += 1;
            prefix.push(t);
            self.dfs(&s2, &p2, prefix, out, cap);
            prefix.pop();
            if out.len() >= cap {
                return;
            }
        }
    }

    /// Seeded random feasible walks, deduplicated; returns up to
    /// `count` distinct schedules (fewer only if the space is smaller).
    pub fn seeded(&self, seed: u64, count: usize) -> Vec<Vec<usize>> {
        let mut rng = SplitMix64::new(seed);
        let mut seen: BTreeSet<Vec<usize>> = BTreeSet::new();
        let mut out: Vec<Vec<usize>> = Vec::new();
        let mut attempts = 0usize;
        while out.len() < count && attempts < count.saturating_mul(100) + 100 {
            attempts += 1;
            if let Some(s) = self.random_walk(&mut rng) {
                if seen.insert(s.clone()) {
                    out.push(s);
                }
            }
        }
        out
    }

    fn random_walk(&self, rng: &mut SplitMix64) -> Option<Vec<usize>> {
        let n = self.scripts.len();
        let mut sim = SimState::new(n);
        let mut progress = vec![0usize; n];
        let mut sched: Vec<usize> = Vec::new();
        loop {
            if (0..n).all(|t| progress[t] >= self.scripts[t].ops.len()) {
                return Some(sched);
            }
            let choices: Vec<usize> = (0..n)
                .filter(|&t| {
                    progress[t] < self.scripts[t].ops.len()
                        && sim.feasible(t, &self.scripts[t].ops[progress[t]])
                })
                .collect();
            if choices.is_empty() {
                return None; // dead end (e.g. all remaining ops blocked)
            }
            let t = choices[rng.next_range(choices.len())];
            let op = self.scripts[t].ops[progress[t]].clone();
            sim.step(t, &op);
            sim.take_freed();
            progress[t] += 1;
            sched.push(t);
        }
    }

    /// Run every enumerated schedule (up to `cap`); error messages name
    /// the exact schedule so it can be replayed with `run_schedule`.
    pub fn run_enumerated(&self, cap: usize) -> Result<Report, String> {
        self.run_set(self.enumerate(cap))
    }

    /// Run `count` distinct seeded schedules.
    pub fn run_seeded(&self, seed: u64, count: usize) -> Result<Report, String> {
        self.run_set(self.seeded(seed, count))
    }

    fn run_set(&self, schedules: Vec<Vec<usize>>) -> Result<Report, String> {
        let mut report = Report::default();
        for s in &schedules {
            let oc = self
                .run_schedule(s)
                .map_err(|e| format!("schedule {:?}: {}", s, e))?;
            report.schedules += 1;
            report.reads_checked += oc.reads;
            report.publishes += oc.publishes;
            if oc.final_epoch > report.max_epoch {
                report.max_epoch = oc.final_epoch;
            }
        }
        Ok(report)
    }

    /// Execute one schedule deterministically and run the full check
    /// battery (see module docs).  `schedule[i]` names the thread that
    /// takes turn `i`; the op is that thread's next unexecuted op.
    pub fn run_schedule(&self, schedule: &[usize]) -> Result<ScheduleOutcomePub, String> {
        let outcome = self.run_schedule_inner(schedule)?;
        Ok(ScheduleOutcomePub {
            reads: outcome.reads,
            publishes: outcome.publishes,
            final_epoch: outcome.final_epoch,
        })
    }

    fn run_schedule_inner(&self, schedule: &[usize]) -> Result<ScheduleOutcome, String> {
        let n = self.scripts.len();
        let total = self.rows * self.cols * self.classes;
        let plane = Arc::new(CounterPlane::new(
            &vec![0.0f32; total],
            &vec![0.0f32; self.classes],
            self.cols,
            self.classes,
        ));
        let (done_tx, done_rx) = channel::<(usize, Done)>();
        let mut cmd_txs: Vec<Sender<Cmd>> = Vec::new();
        let mut handles = Vec::new();
        for t in 0..n {
            let (ctx, crx) = channel::<Cmd>();
            cmd_txs.push(ctx);
            let p2 = Arc::clone(&plane);
            let d2 = done_tx.clone();
            handles.push(thread::spawn(move || worker(p2, crx, d2, t)));
        }
        drop(done_tx);

        let mut early: Vec<(usize, Done)> = Vec::new();
        let mut sim = SimState::new(n);
        let mut progress = vec![0usize; n];
        // Model of the published state, per epoch, plus the pending
        // queue and the global arrival order of every delta.
        let mut published: (Vec<f32>, Vec<f32>) =
            (vec![0.0f32; total], vec![0.0f32; self.classes]);
        let mut expected: Vec<(Vec<f32>, Vec<f32>)> = vec![published.clone()];
        let mut queued: Vec<(Vec<u32>, usize, f32)> = Vec::new();
        let mut all: Vec<(Vec<u32>, usize, f32)> = Vec::new();
        let mut outcome = ScheduleOutcome { reads: 0, publishes: 0, final_epoch: 0 };

        for (step_no, &t) in schedule.iter().enumerate() {
            if t >= n {
                return Err(format!("step {}: unknown thread {}", step_no, t));
            }
            let op = match self.scripts[t].ops.get(progress[t]) {
                Some(op) => op.clone(),
                None => {
                    return Err(format!(
                        "step {}: thread {} has no ops left",
                        step_no, t
                    ))
                }
            };
            if !sim.feasible(t, &op) {
                return Err(format!(
                    "step {}: op {:?} on thread {} is infeasible (would \
                     block forever)",
                    step_no, op, t
                ));
            }
            self.exec_step(
                t,
                &op,
                &cmd_txs,
                &done_rx,
                &mut early,
                &mut sim,
                &mut published,
                &mut expected,
                &mut queued,
                &mut all,
                &mut outcome,
            )?;
            progress[t] += 1;
        }

        // Drain: drop held pins (releasing any parked publish), then
        // flush anything still queued through a final publish.
        for t in 0..n {
            if sim.pins[t].is_some() {
                self.exec_step(
                    t,
                    &Op::Unpin,
                    &cmd_txs,
                    &done_rx,
                    &mut early,
                    &mut sim,
                    &mut published,
                    &mut expected,
                    &mut queued,
                    &mut all,
                    &mut outcome,
                )?;
            }
        }
        if sim.pending > 0 {
            self.exec_step(
                0,
                &Op::Publish,
                &cmd_txs,
                &done_rx,
                &mut early,
                &mut sim,
                &mut published,
                &mut expected,
                &mut queued,
                &mut all,
                &mut outcome,
            )?;
        }

        // Check battery 1: live snapshot == model fold.
        {
            let pin = plane.pin();
            if pin.epoch != sim.epoch {
                return Err(format!(
                    "final epoch {} != model {}",
                    pin.epoch, sim.epoch
                ));
            }
            if !bits_eq(&pin.counters, &published.0)
                || !bits_eq(&pin.alpha_sums, &published.1)
            {
                return Err("final plane differs bitwise from the model fold"
                    .to_string());
            }
        }
        // Check battery 2: both internal buffers agree bitwise.
        {
            let (a, b) = plane.snapshot_both();
            if !bits_eq(&a.counters, &b.counters)
                || !bits_eq(&a.alpha_sums, &b.alpha_sums)
            {
                return Err(
                    "internal buffers diverged: replay queue did not fold \
                     every delta exactly once"
                        .to_string(),
                );
            }
        }
        // Check battery 3: single-pass rebuild in global arrival order.
        {
            let rebuilt = CounterPlane::new(
                &vec![0.0f32; total],
                &vec![0.0f32; self.classes],
                self.cols,
                self.classes,
            );
            for (cols, class, alpha) in &all {
                rebuilt.apply(cols, *class, *alpha);
            }
            rebuilt.publish();
            let rp = rebuilt.pin();
            if !bits_eq(&rp.counters, &published.0)
                || !bits_eq(&rp.alpha_sums, &published.1)
            {
                return Err(
                    "single-pass rebuild differs bitwise from the streamed \
                     plane"
                        .to_string(),
                );
            }
        }

        outcome.final_epoch = sim.epoch;
        drop(cmd_txs);
        for h in handles {
            let _ = h.join();
        }
        Ok(outcome)
    }

    #[allow(clippy::too_many_arguments)]
    fn exec_step(
        &self,
        t: usize,
        op: &Op,
        cmd_txs: &[Sender<Cmd>],
        done_rx: &Receiver<(usize, Done)>,
        early: &mut Vec<(usize, Done)>,
        sim: &mut SimState,
        published: &mut (Vec<f32>, Vec<f32>),
        expected: &mut Vec<(Vec<f32>, Vec<f32>)>,
        queued: &mut Vec<(Vec<u32>, usize, f32)>,
        all: &mut Vec<(Vec<u32>, usize, f32)>,
        outcome: &mut ScheduleOutcome,
    ) -> Result<(), String> {
        match op {
            Op::Pin => {
                send(cmd_txs, t, Cmd::Pin)?;
                match recv_from(done_rx, early, t)? {
                    Done::Pinned(e) if e == sim.epoch => {}
                    Done::Pinned(e) => {
                        return Err(format!(
                            "thread {} pinned epoch {} but model is at {}",
                            t, e, sim.epoch
                        ))
                    }
                    Done::Fail(m) => return Err(m),
                    _ => return Err("unexpected reply to Pin".to_string()),
                }
            }
            Op::ReadCheck => {
                let e = match sim.pins[t] {
                    Some(e) => e,
                    None => return Err("read-check without pin".to_string()),
                };
                let exp = &expected[e as usize];
                send(
                    cmd_txs,
                    t,
                    Cmd::ReadCheck {
                        counters: exp.0.clone(),
                        alpha: exp.1.clone(),
                        epoch: e,
                    },
                )?;
                match recv_from(done_rx, early, t)? {
                    Done::Ok => outcome.reads += 1,
                    Done::Fail(m) => return Err(m),
                    _ => return Err("unexpected reply to ReadCheck".to_string()),
                }
            }
            Op::Unpin => {
                send(cmd_txs, t, Cmd::Unpin)?;
                match recv_from(done_rx, early, t)? {
                    Done::Ok => {}
                    Done::Fail(m) => return Err(m),
                    _ => return Err("unexpected reply to Unpin".to_string()),
                }
            }
            Op::Apply { cols, class, alpha } => {
                send(
                    cmd_txs,
                    t,
                    Cmd::Apply {
                        cols: cols.clone(),
                        class: *class,
                        alpha: *alpha,
                    },
                )?;
                match recv_from(done_rx, early, t)? {
                    Done::Count(got) => {
                        if got != queued.len() + 1 {
                            return Err(format!(
                                "apply reported {} pending, model has {}",
                                got,
                                queued.len() + 1
                            ));
                        }
                    }
                    Done::Fail(m) => return Err(m),
                    _ => return Err("unexpected reply to Apply".to_string()),
                }
                queued.push((cols.clone(), *class, *alpha));
                all.push((cols.clone(), *class, *alpha));
            }
            Op::Publish => {
                if sim.pending == 0 {
                    send(cmd_txs, t, Cmd::Publish)?;
                    match recv_from(done_rx, early, t)? {
                        Done::Epoch(e) if e == sim.epoch => {}
                        Done::Epoch(e) => {
                            return Err(format!(
                                "no-op publish returned epoch {}, model {}",
                                e, sim.epoch
                            ))
                        }
                        Done::Fail(m) => return Err(m),
                        _ => {
                            return Err("unexpected reply to Publish".to_string())
                        }
                    }
                } else {
                    let pre = sim.epoch;
                    for d in queued.iter() {
                        fold(published, self.cols, self.classes, d);
                    }
                    queued.clear();
                    expected.push((published.0.clone(), published.1.clone()));
                    outcome.publishes += 1;
                    let parks = sim
                        .pins
                        .iter()
                        .enumerate()
                        .any(|(o, p)| o != t && *p == Some(pre));
                    send(cmd_txs, t, Cmd::Publish)?;
                    if !parks {
                        match recv_from(done_rx, early, t)? {
                            Done::Epoch(e) if e == pre + 1 => {}
                            Done::Epoch(e) => {
                                return Err(format!(
                                    "publish returned epoch {}, model {}",
                                    e,
                                    pre + 1
                                ))
                            }
                            Done::Fail(m) => return Err(m),
                            _ => {
                                return Err(
                                    "unexpected reply to Publish".to_string()
                                )
                            }
                        }
                    }
                    // else: parked — its Epoch reply is collected when
                    // the last blocking pin drops (see below).
                }
            }
        }
        sim.step(t, op);
        if let Some(freed) = sim.take_freed() {
            match recv_from(done_rx, early, freed)? {
                Done::Epoch(_) => {}
                Done::Fail(m) => return Err(m),
                _ => {
                    return Err(
                        "unexpected reply from released publish".to_string()
                    )
                }
            }
        }
        Ok(())
    }
}

/// Public view of one schedule's outcome.
#[derive(Clone, Debug)]
pub struct ScheduleOutcomePub {
    pub reads: u64,
    pub publishes: u64,
    pub final_epoch: u64,
}

fn send(cmd_txs: &[Sender<Cmd>], t: usize, cmd: Cmd) -> Result<(), String> {
    cmd_txs[t]
        .send(cmd)
        .map_err(|_| format!("worker {} exited prematurely", t))
}

fn recv_from(
    rx: &Receiver<(usize, Done)>,
    early: &mut Vec<(usize, Done)>,
    want: usize,
) -> Result<Done, String> {
    if let Some(pos) = early.iter().position(|(id, _)| *id == want) {
        return Ok(early.remove(pos).1);
    }
    loop {
        match rx.recv_timeout(STEP_TIMEOUT) {
            Ok((id, d)) => {
                if id == want {
                    return Ok(d);
                }
                early.push((id, d));
            }
            Err(_) => {
                return Err(format!(
                    "timed out waiting for worker {} (deadlock in the \
                     schedule driver?)",
                    want
                ))
            }
        }
    }
}

/// Mirror of `CounterPlane::apply_to`: the exact per-cell fold order the
/// plane uses, so a reordered replay shows up as a bit difference.
fn fold(
    buf: &mut (Vec<f32>, Vec<f32>),
    cols: usize,
    n_classes: usize,
    d: &(Vec<u32>, usize, f32),
) {
    for (l, &c) in d.0.iter().enumerate() {
        buf.0[(l * cols + c as usize) * n_classes + d.1] += d.2;
    }
    buf.1[d.1] += d.2;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_thread_enumeration_is_substantial_and_passes() {
        let h = Interleaver::standard(2);
        let schedules = h.enumerate(4096);
        assert!(
            schedules.len() >= 100,
            "only {} schedules enumerated",
            schedules.len()
        );
        // Schedules are distinct by construction.
        let set: BTreeSet<Vec<usize>> = schedules.iter().cloned().collect();
        assert_eq!(set.len(), schedules.len());
        // Smoke-run a slice here; tests/audit_interleave.rs runs the
        // full battery.
        let r = h
            .run_set_public(schedules.into_iter().take(12).collect())
            .expect("first schedules must pass");
        assert_eq!(r.schedules, 12);
    }

    #[test]
    fn publish_racing_reader_pin_replays_exactly() {
        // reader pins, writer applies + publishes (parks on the pin),
        // reader validates its snapshot mid-park, then unpins.
        let h = Interleaver::standard(2);
        // thread 1: Pin; thread 0: Apply, Apply, Publish (parks);
        // thread 1: ReadCheck (stable old snapshot), Unpin (releases);
        // then the rest of both scripts.
        let schedule = vec![1, 0, 0, 0, 1, 1, 0, 0, 1, 1, 1];
        let oc = h.run_schedule(&schedule).expect("schedule must pass");
        assert!(oc.reads >= 1);
        assert!(oc.publishes >= 1);
    }

    #[test]
    fn infeasible_schedules_are_rejected_not_deadlocked() {
        let h = Interleaver::standard(2);
        // Thread 1's first op is Pin; its second is ReadCheck.  Running
        // thread 0's Publish twice first is fine, but a ReadCheck
        // without a pin (thread 1 never pinned) cannot be scheduled:
        // start with ReadCheck by giving thread 1 two turns after an
        // Unpin... simplest: a schedule overrunning a script errs.
        let err = h.run_schedule(&vec![0; 20]).unwrap_err();
        assert!(err.contains("no ops left"), "{}", err);
    }

    #[test]
    fn seeded_walks_are_deterministic() {
        let h = Interleaver::standard(3);
        let a = h.seeded(0xC0FFEE, 25);
        let b = h.seeded(0xC0FFEE, 25);
        assert_eq!(a, b);
        assert!(a.len() >= 25);
        h.run_set_public(a).expect("seeded schedules must pass");
    }

    impl Interleaver {
        fn run_set_public(&self, s: Vec<Vec<usize>>) -> Result<Report, String> {
            self.run_set(s)
        }
    }
}
