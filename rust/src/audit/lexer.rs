//! A lightweight Rust lexer for the audit pass.
//!
//! The rules in [`super::rules`] match **token** patterns, never raw text,
//! so `unsafe` inside a string literal, a commented-out `Ordering::SeqCst`,
//! or a raw-string fixture can never produce a false positive.  The lexer
//! is deliberately small: it distinguishes exactly the token classes the
//! rules need (identifiers, literals, punctuation, and — crucially —
//! comments with their line spans, because the annotation syntax lives in
//! comments).  It is not a full Rust front-end: numeric literal suffixes,
//! multi-character operators, and attribute grammar are left to the rule
//! layer, which only ever looks at adjacent significant tokens.
//!
//! Handled corner cases (each locked by a unit test in `rules.rs`):
//! nested block comments, raw strings `r#"…"#` (any hash depth), byte and
//! raw-byte strings, byte chars `b'x'`, char-vs-lifetime disambiguation
//! (`'a'` vs `'static`), raw identifiers `r#fn`, escaped quotes, and
//! multi-line strings (their interior lines count as code lines, not
//! comment lines).

/// Token classes.  Comments are real tokens here — the annotation rules
/// need them — but every matcher in `rules.rs` walks the "significant"
/// (non-comment) token sequence.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum TokKind {
    /// Identifier or keyword (`unsafe`, `Ordering`, `as`, …).
    Ident,
    /// Numeric literal (including suffix characters).
    Num,
    /// String literal of any flavor; `text` holds the *inner* contents.
    Str,
    /// Char or byte-char literal.
    Char,
    /// Lifetime (`'a`, `'static`).
    Lifetime,
    /// Single punctuation character.
    Punct,
    /// `// …` comment (text includes the slashes).
    LineComment,
    /// `/* … */` comment, possibly nested/multi-line.
    BlockComment,
}

/// One token with its 1-based line span.
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    /// Line the token starts on (1-based).
    pub line: u32,
    /// Line the token ends on (== `line` for single-line tokens).
    pub end_line: u32,
}

impl Tok {
    fn one(kind: TokKind, text: String, line: u32) -> Tok {
        Tok { kind, text, line, end_line: line }
    }
}

/// Lex `src` into tokens.  Never fails: unterminated constructs are
/// closed at end-of-file (the audit must not crash on a half-written
/// file; it will simply report what it can see).
pub fn lex(src: &str) -> Vec<Tok> {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut toks: Vec<Tok> = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Comments.
        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            let start = i;
            while i < n && chars[i] != '\n' {
                i += 1;
            }
            toks.push(Tok::one(
                TokKind::LineComment,
                chars[start..i].iter().collect(),
                line,
            ));
            continue;
        }
        if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            let start = i;
            let start_line = line;
            let mut depth = 1u32;
            i += 2;
            while i < n && depth > 0 {
                if chars[i] == '/' && i + 1 < n && chars[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if chars[i] == '*' && i + 1 < n && chars[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    if chars[i] == '\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            toks.push(Tok {
                kind: TokKind::BlockComment,
                text: chars[start..i].iter().collect(),
                line: start_line,
                end_line: line,
            });
            continue;
        }
        // Raw identifier `r#ident` (checked before raw strings: `r#"` has
        // a quote where the identifier would start).
        if c == 'r'
            && i + 2 < n
            && chars[i + 1] == '#'
            && (chars[i + 2].is_alphabetic() || chars[i + 2] == '_')
        {
            let start = i;
            i += 2;
            while i < n && (chars[i].is_alphanumeric() || chars[i] == '_') {
                i += 1;
            }
            toks.push(Tok::one(
                TokKind::Ident,
                chars[start..i].iter().collect(),
                line,
            ));
            continue;
        }
        // Raw / raw-byte strings: r"…", r#"…"#, br"…", br#"…"#.
        if c == 'r' || c == 'b' {
            let mut j = i + 1;
            if c == 'b' && j < n && chars[j] == 'r' {
                j += 1;
            }
            let raw_ok = (c == 'r' && j == i + 1) || (c == 'b' && j == i + 2);
            if raw_ok {
                let mut hashes = 0usize;
                let mut k = j;
                while k < n && chars[k] == '#' {
                    hashes += 1;
                    k += 1;
                }
                if k < n && chars[k] == '"' {
                    let start_line = line;
                    let content_start = k + 1;
                    let mut m = content_start;
                    while m < n {
                        if chars[m] == '"' {
                            let mut closed = true;
                            for t in 0..hashes {
                                if m + 1 + t >= n || chars[m + 1 + t] != '#' {
                                    closed = false;
                                    break;
                                }
                            }
                            if closed {
                                break;
                            }
                        }
                        if chars[m] == '\n' {
                            line += 1;
                        }
                        m += 1;
                    }
                    let text: String =
                        chars[content_start..m.min(n)].iter().collect();
                    toks.push(Tok {
                        kind: TokKind::Str,
                        text,
                        line: start_line,
                        end_line: line,
                    });
                    i = (m + 1 + hashes).min(n);
                    continue;
                }
            }
            // Byte string b"…" / byte char b'x'.
            if c == 'b' && i + 1 < n && chars[i + 1] == '"' {
                let start_line = line;
                let (text, ni, nl) = lex_dq_string(&chars, i + 1, line);
                toks.push(Tok {
                    kind: TokKind::Str,
                    text,
                    line: start_line,
                    end_line: nl,
                });
                i = ni;
                line = nl;
                continue;
            }
            if c == 'b' && i + 1 < n && chars[i + 1] == '\'' {
                let (ni, nl) = skip_char_literal(&chars, i + 1, line);
                toks.push(Tok::one(TokKind::Char, String::new(), line));
                i = ni;
                line = nl;
                continue;
            }
            // Fall through: plain identifier starting with r/b.
        }
        // String literal.
        if c == '"' {
            let start_line = line;
            let (text, ni, nl) = lex_dq_string(&chars, i, line);
            toks.push(Tok {
                kind: TokKind::Str,
                text,
                line: start_line,
                end_line: nl,
            });
            i = ni;
            line = nl;
            continue;
        }
        // Char literal vs lifetime.
        if c == '\'' {
            if i + 1 < n && chars[i + 1] == '\\' {
                let (ni, nl) = skip_char_literal(&chars, i, line);
                toks.push(Tok::one(TokKind::Char, String::new(), line));
                i = ni;
                line = nl;
                continue;
            }
            if i + 2 < n && chars[i + 1] != '\'' && chars[i + 2] == '\'' {
                toks.push(Tok::one(
                    TokKind::Char,
                    chars[i + 1].to_string(),
                    line,
                ));
                i += 3;
                continue;
            }
            // Lifetime: `'` followed by identifier characters.
            let start = i;
            i += 1;
            while i < n && (chars[i].is_alphanumeric() || chars[i] == '_') {
                i += 1;
            }
            toks.push(Tok::one(
                TokKind::Lifetime,
                chars[start..i].iter().collect(),
                line,
            ));
            continue;
        }
        // Numeric literal.  A `.` continues the literal only when a digit
        // follows, so `pair.0.unwrap()` still yields an `unwrap` token
        // and `0..n` yields `0`, `.`, `.`, `n`.
        if c.is_ascii_digit() {
            let start = i;
            i += 1;
            while i < n {
                let d = chars[i];
                if d.is_alphanumeric() || d == '_' {
                    i += 1;
                    continue;
                }
                if d == '.' && i + 1 < n && chars[i + 1].is_ascii_digit() {
                    i += 1;
                    continue;
                }
                if (d == '+' || d == '-')
                    && (chars[i - 1] == 'e' || chars[i - 1] == 'E')
                {
                    i += 1;
                    continue;
                }
                break;
            }
            toks.push(Tok::one(
                TokKind::Num,
                chars[start..i].iter().collect(),
                line,
            ));
            continue;
        }
        // Identifier / keyword.
        if c.is_alphabetic() || c == '_' {
            let start = i;
            i += 1;
            while i < n && (chars[i].is_alphanumeric() || chars[i] == '_') {
                i += 1;
            }
            toks.push(Tok::one(
                TokKind::Ident,
                chars[start..i].iter().collect(),
                line,
            ));
            continue;
        }
        // Everything else: one punctuation character per token.
        toks.push(Tok::one(TokKind::Punct, c.to_string(), line));
        i += 1;
    }
    toks
}

/// Lex a double-quoted string starting at `chars[i] == '"'`.  Returns the
/// inner text (escapes kept verbatim), the index past the closing quote,
/// and the updated line counter.
fn lex_dq_string(chars: &[char], mut i: usize, mut line: u32) -> (String, usize, u32) {
    let n = chars.len();
    i += 1; // opening quote
    let mut out = String::new();
    while i < n {
        let c = chars[i];
        if c == '\\' {
            if i + 1 < n {
                let e = chars[i + 1];
                if e == '\n' {
                    line += 1;
                }
                out.push('\\');
                out.push(e);
                i += 2;
                continue;
            }
            i += 1;
            continue;
        }
        if c == '"' {
            i += 1;
            break;
        }
        if c == '\n' {
            line += 1;
        }
        out.push(c);
        i += 1;
    }
    (out, i, line)
}

/// Skip a char/byte-char literal starting at `chars[i] == '\''`; returns
/// the index past the closing quote and the updated line counter.
fn skip_char_literal(chars: &[char], mut i: usize, mut line: u32) -> (usize, u32) {
    let n = chars.len();
    i += 1; // opening quote
    while i < n {
        let c = chars[i];
        if c == '\\' {
            i += 2;
            continue;
        }
        if c == '\'' {
            i += 1;
            break;
        }
        if c == '\n' {
            line += 1;
        }
        i += 1;
    }
    (i, line)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn strings_and_comments_are_not_code() {
        let toks = kinds("let s = \"unsafe { Ordering::SeqCst }\"; // unsafe");
        let idents: Vec<&str> = toks
            .iter()
            .filter(|(k, _)| *k == TokKind::Ident)
            .map(|(_, t)| t.as_str())
            .collect();
        assert_eq!(idents, vec!["let", "s"]);
        assert!(toks.iter().any(|(k, t)| *k == TokKind::Str && t.contains("SeqCst")));
        assert!(toks.iter().any(|(k, _)| *k == TokKind::LineComment));
    }

    #[test]
    fn raw_strings_any_hash_depth() {
        let toks = kinds("r##\"x \"# unsafe\"## + b\"p\\\"q\" + br#\"z\"#");
        let strs: Vec<&str> = toks
            .iter()
            .filter(|(k, _)| *k == TokKind::Str)
            .map(|(_, t)| t.as_str())
            .collect();
        assert_eq!(strs, vec!["x \"# unsafe", "p\\\"q", "z"]);
    }

    #[test]
    fn nested_block_comments() {
        let toks = kinds("a /* outer /* inner */ still */ b");
        let idents: Vec<&str> = toks
            .iter()
            .filter(|(k, _)| *k == TokKind::Ident)
            .map(|(_, t)| t.as_str())
            .collect();
        assert_eq!(idents, vec!["a", "b"]);
    }

    #[test]
    fn char_vs_lifetime() {
        let toks = kinds("'a' 'x 'static '\\n' b'z'");
        let counts = |k: TokKind| toks.iter().filter(|(kk, _)| *kk == k).count();
        assert_eq!(counts(TokKind::Char), 3);
        assert_eq!(counts(TokKind::Lifetime), 2);
    }

    #[test]
    fn tuple_field_access_does_not_swallow_method() {
        let toks = kinds("pair.0.unwrap()");
        assert!(toks
            .iter()
            .any(|(k, t)| *k == TokKind::Ident && t == "unwrap"));
        let toks = kinds("for i in 0..max_len {}");
        assert!(toks
            .iter()
            .any(|(k, t)| *k == TokKind::Ident && t == "max_len"));
    }

    #[test]
    fn raw_identifiers_and_numbers() {
        let toks = kinds("r#fn 1.5e-3 0xFFu32 1e999");
        assert_eq!(toks[0], (TokKind::Ident, "r#fn".to_string()));
        assert_eq!(toks[1], (TokKind::Num, "1.5e-3".to_string()));
        assert_eq!(toks[2], (TokKind::Num, "0xFFu32".to_string()));
        assert_eq!(toks[3], (TokKind::Num, "1e999".to_string()));
    }

    #[test]
    fn line_numbers_track_multiline_tokens() {
        let src = "a\n/* two\nlines */\nb \"s1\ns2\"\nc";
        let toks = lex(src);
        let find = |name: &str| toks.iter().find(|t| t.text == name).map(|t| t.line);
        assert_eq!(find("a"), Some(1));
        assert_eq!(find("b"), Some(4));
        assert_eq!(find("c"), Some(6));
        let block = toks.iter().find(|t| t.kind == TokKind::BlockComment);
        let block = match block {
            Some(b) => b,
            None => return assert!(false, "no block comment"),
        };
        assert_eq!((block.line, block.end_line), (2, 3));
    }
}
