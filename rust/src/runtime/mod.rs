//! PJRT runtime — loads the AOT artifacts (`*.hlo.txt`, produced once by
//! `make artifacts`) and executes them from the rust request path.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 serialized protos carry 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).  All executables are
//! compiled once at load and reused; the AOT batch size is fixed (32) and
//! the executor pads partial batches.
//!
//! The XLA binding (`xla` crate) is only available on machines with the
//! PJRT toolchain installed, so the real implementation is gated behind
//! the `pjrt` cargo feature.  Without it this module keeps the exact same
//! API — [`Runtime::cpu`] returns a descriptive error and no
//! [`Executable`] can ever be constructed — which lets the coordinator,
//! registry, CLI, and tests compile and run everywhere; PJRT lanes then
//! surface "engine init failed" responses instead of panicking.

pub mod registry;

pub use registry::ModelRegistry;

use anyhow::Result;

/// A compiled, ready-to-run XLA executable with a fixed (batch, dim)
/// input signature and scalar-per-row output.
pub struct Executable {
    #[cfg(feature = "pjrt")]
    exe: xla::PjRtLoadedExecutable,
    /// Proof that a stub Executable can never be constructed.
    #[cfg(not(feature = "pjrt"))]
    _uninhabited: std::convert::Infallible,
    pub batch: usize,
    pub dim: usize,
}

/// Wrapper over one PJRT CPU client and its loaded executables.
pub struct Runtime {
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    #[cfg(not(feature = "pjrt"))]
    _private: (),
}

#[cfg(feature = "pjrt")]
mod imp {
    use super::{Executable, Runtime};
    use anyhow::{Context, Result};
    use std::path::Path;

    impl Runtime {
        pub fn cpu() -> Result<Self> {
            let client =
                xla::PjRtClient::cpu().context("create PJRT CPU client")?;
            Ok(Self { client })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load an HLO-text artifact with a declared (batch, dim) signature.
        pub fn load_hlo<P: AsRef<Path>>(
            &self,
            path: P,
            batch: usize,
            dim: usize,
        ) -> Result<Executable> {
            let proto = xla::HloModuleProto::from_text_file(
                path.as_ref().to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parse HLO text {:?}", path.as_ref()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compile {:?}", path.as_ref()))?;
            Ok(Executable { exe, batch, dim })
        }
    }

    impl Executable {
        /// Run one padded batch: `rows.len() <= batch`, each row `dim`
        /// floats.  Returns one scalar per input row.
        pub fn run_batch(&self, rows: &[&[f32]]) -> Result<Vec<f32>> {
            anyhow::ensure!(
                rows.len() <= self.batch,
                "batch {} exceeds executable batch {}",
                rows.len(),
                self.batch
            );
            let mut flat = vec![0.0f32; self.batch * self.dim];
            for (i, row) in rows.iter().enumerate() {
                anyhow::ensure!(
                    row.len() == self.dim,
                    "row {} has dim {} != {}",
                    i,
                    row.len(),
                    self.dim
                );
                flat[i * self.dim..(i + 1) * self.dim].copy_from_slice(row);
            }
            let lit = xla::Literal::vec1(&flat)
                .reshape(&[self.batch as i64, self.dim as i64])?;
            let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0]
                .to_literal_sync()?;
            // AOT lowers with return_tuple=True: unwrap the 1-tuple.
            let out = result.to_tuple1()?;
            let values = out.to_vec::<f32>()?;
            anyhow::ensure!(
                values.len() == self.batch,
                "output size {} != batch {}",
                values.len(),
                self.batch
            );
            Ok(values[..rows.len()].to_vec())
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use super::{Executable, Runtime};
    use anyhow::Result;
    use std::path::Path;

    impl Runtime {
        pub fn cpu() -> Result<Self> {
            anyhow::bail!(
                "repsketch was built without the `pjrt` feature; \
                 PJRT backends are unavailable on this machine"
            )
        }

        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }

        pub fn load_hlo<P: AsRef<Path>>(
            &self,
            _path: P,
            _batch: usize,
            _dim: usize,
        ) -> Result<Executable> {
            anyhow::bail!("repsketch was built without the `pjrt` feature")
        }
    }

    impl Executable {
        pub fn run_batch(&self, _rows: &[&[f32]]) -> Result<Vec<f32>> {
            // `Executable` is uninhabited without the feature.
            match self._uninhabited {}
        }
    }
}

impl Executable {
    /// Convenience: run many rows by chunking into padded batches.
    pub fn run_all(&self, x: &[f32], dim: usize) -> Result<Vec<f32>> {
        anyhow::ensure!(dim == self.dim, "dim mismatch");
        let n = x.len() / dim;
        let mut out = Vec::with_capacity(n);
        for chunk_start in (0..n).step_by(self.batch) {
            let end = (chunk_start + self.batch).min(n);
            let rows: Vec<&[f32]> = (chunk_start..end)
                .map(|i| &x[i * dim..(i + 1) * dim])
                .collect();
            out.extend(self.run_batch(&rows)?);
        }
        Ok(out)
    }

    /// Whether this build can ever produce a PJRT executable.
    pub fn supported() -> bool {
        cfg!(feature = "pjrt")
    }
}

#[cfg(all(test, not(feature = "pjrt")))]
mod tests {
    use super::Runtime;

    #[test]
    fn stub_runtime_reports_missing_feature() {
        let err = Runtime::cpu().unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
