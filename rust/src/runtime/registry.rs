//! Model registry: discovers dataset artifacts, loads executables and
//! binary weights on demand, and hands the coordinator a uniform view of
//! every backend variant (NN-PJRT / NN-rust / Kernel-PJRT / Kernel-rust /
//! Representer Sketch).

use super::{Executable, Runtime};
use crate::data::Task;
use crate::kernel::{KernelModel, KernelParams};
use crate::nn::Mlp;
use crate::sketch::{RaceSketch, SketchConfig};
use crate::util::json::{self, Json};
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Parsed `meta.json` for one dataset.
#[derive(Clone, Debug)]
pub struct DatasetMeta {
    pub name: String,
    pub dim: usize,
    pub task: Task,
    pub hidden: Vec<usize>,
    pub nn_params: usize,
    pub aot_batch: usize,
    pub kernel_p: usize,
    pub kernel_m: usize,
    pub kernel_width: f64,
    pub k_per_row: usize,
    pub default_rows: usize,
    pub default_cols: usize,
    pub train_nn_metric: f64,
    pub train_kernel_metric: f64,
    /// (artifact stem, param count) for figure-2 baselines.
    pub baselines: Vec<(String, usize)>,
}

impl DatasetMeta {
    pub fn load(dir: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(dir.join("meta.json"))
            .with_context(|| format!("read {:?}/meta.json", dir))?;
        let j = json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parse meta.json: {e}"))?;
        let req = |p: &[&str]| -> Result<&Json> {
            j.at(p).with_context(|| format!("meta.json missing {p:?}"))
        };
        let mut baselines = Vec::new();
        if let Some(Json::Obj(b)) = j.get("baselines") {
            for (k, v) in b {
                let n = v
                    .get("nnz")
                    .or_else(|| v.get("params"))
                    .and_then(|x| x.as_usize())
                    .unwrap_or(0);
                baselines.push((k.clone(), n));
            }
        }
        Ok(Self {
            name: req(&["name"])?.as_str().unwrap_or_default().to_string(),
            dim: req(&["dim"])?.as_usize().context("dim")?,
            task: Task::from_str(
                req(&["task"])?.as_str().context("task")?,
            )?,
            hidden: req(&["hidden"])?
                .as_arr()
                .context("hidden")?
                .iter()
                .filter_map(|v| v.as_usize())
                .collect(),
            nn_params: req(&["nn_params"])?.as_usize().context("nn_params")?,
            aot_batch: req(&["aot_batch"])?.as_usize().unwrap_or(32),
            kernel_p: req(&["kernel", "p"])?.as_usize().context("p")?,
            kernel_m: req(&["kernel", "m"])?.as_usize().context("m")?,
            kernel_width: req(&["kernel", "width"])?
                .as_f64()
                .context("width")?,
            k_per_row: req(&["kernel", "k_per_row"])?
                .as_usize()
                .context("k")?,
            default_rows: req(&["kernel", "default_rows"])?
                .as_usize()
                .context("rows")?,
            default_cols: req(&["kernel", "default_cols"])?
                .as_usize()
                .context("cols")?,
            train_nn_metric: j
                .at(&["train_metrics", "nn"])
                .and_then(|v| v.as_f64())
                .unwrap_or(f64::NAN),
            train_kernel_metric: j
                .at(&["train_metrics", "kernel"])
                .and_then(|v| v.as_f64())
                .unwrap_or(f64::NAN),
            baselines,
        })
    }
}

/// All loaded artifacts for one dataset.
pub struct DatasetBundle {
    pub meta: DatasetMeta,
    pub dir: PathBuf,
    pub mlp: Mlp,
    pub kernel: KernelModel,
    pub sketch: RaceSketch,
    /// PJRT executables (None until `load_executables`).
    pub nn_exe: Option<Executable>,
    pub kernel_exe: Option<Executable>,
}

impl DatasetBundle {
    /// Load binary artifacts (cheap; no XLA compilation).
    pub fn load(root: &Path, name: &str) -> Result<Self> {
        let dir = root.join(name);
        let meta = DatasetMeta::load(&dir)?;
        let mlp = Mlp::load(dir.join("nn_weights.bin"))?;
        let kp = KernelParams::load(dir.join("kernel_params.bin"))?;
        let sketch = RaceSketch::build(&kp, &SketchConfig::default());
        anyhow::ensure!(mlp.input_dim() == meta.dim, "nn dim mismatch");
        anyhow::ensure!(kp.d == meta.dim, "kernel dim mismatch");
        Ok(Self {
            meta,
            dir,
            mlp,
            kernel: KernelModel::new(kp),
            sketch,
            nn_exe: None,
            kernel_exe: None,
        })
    }

    /// Compile the PJRT executables (slow; only when the XLA path is
    /// actually served).
    pub fn load_executables(&mut self, rt: &Runtime) -> Result<()> {
        if self.nn_exe.is_none() {
            self.nn_exe = Some(rt.load_hlo(
                self.dir.join("nn.hlo.txt"),
                self.meta.aot_batch,
                self.meta.dim,
            )?);
        }
        if self.kernel_exe.is_none() {
            self.kernel_exe = Some(rt.load_hlo(
                self.dir.join("kernel.hlo.txt"),
                self.meta.aot_batch,
                self.meta.dim,
            )?);
        }
        Ok(())
    }

    /// Rebuild the sketch at a different size (Figure-2 sweeps).
    pub fn rebuild_sketch(&mut self, cfg: &SketchConfig) -> Result<()> {
        let kp = KernelParams::load(self.dir.join("kernel_params.bin"))?;
        self.sketch = RaceSketch::build(&kp, cfg);
        Ok(())
    }
}

/// Registry over the whole artifacts tree.
pub struct ModelRegistry {
    pub root: PathBuf,
    pub bundles: Vec<DatasetBundle>,
}

impl ModelRegistry {
    /// Dataset names in canonical paper order.
    pub const DATASETS: [&'static str; 6] =
        ["adult", "phishing", "skin", "susy", "abalone", "yearmsd"];

    pub fn load(root: &Path, names: &[&str]) -> Result<Self> {
        let mut bundles = Vec::new();
        for name in names {
            bundles.push(
                DatasetBundle::load(root, name)
                    .with_context(|| format!("load dataset {name}"))?,
            );
        }
        Ok(Self { root: root.to_path_buf(), bundles })
    }

    pub fn get(&self, name: &str) -> Option<&DatasetBundle> {
        self.bundles.iter().find(|b| b.meta.name == name)
    }

    pub fn get_mut(&mut self, name: &str) -> Option<&mut DatasetBundle> {
        self.bundles.iter_mut().find(|b| b.meta.name == name)
    }
}
