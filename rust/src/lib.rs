//! # repsketch
//!
//! A production-grade reproduction of *"Efficient Inference via Universal
//! LSH Kernel"* (Liu, Coleman, Shrivastava, 2021) — the **Representer
//! Sketch** system: neural-network inference compressed into a weighted
//! RACE sketch queried with add/subtract hashing and counter lookups.
//!
//! The stack has three layers (see `DESIGN.md`):
//!
//! * **L1/L2 (build time, Python)** — Pallas kernels + JAX models, AOT
//!   lowered to HLO text consumed by [`runtime`].
//! * **L3 (this crate)** — the deployment story: [`lsh`] hash families,
//!   the weighted RACE [`sketch`], an exact [`kernel`] density baseline,
//!   a dense/sparse [`nn`] inference engine for the paper's baselines, a
//!   serving [`coordinator`] (router + dynamic batcher), and the
//!   [`experiments`] harness regenerating every table and figure of the
//!   paper's evaluation.

pub mod audit;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod kernel;
pub mod lsh;
pub mod metrics;
pub mod nn;
pub mod runtime;
pub mod shard;
pub mod sketch;
pub mod util;

/// Root of the artifacts tree produced by `make artifacts`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("RS_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
