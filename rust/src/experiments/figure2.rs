//! Figure 2: accuracy versus memory-reduction-rate frontier —
//! Representer Sketch vs one-time pruning, multi-time pruning, and
//! knowledge distillation (panels a–d: adult, phishing, skin, abalone).
//!
//! RS points come from re-building the sketch at a ladder of row counts
//! (no retraining needed — the whole point of sketch-time compression);
//! baseline points come from the pruned / KD artifacts the python
//! pipeline trained.

use crate::data::Dataset;
use crate::kernel::KernelParams;
use crate::nn::{Mlp, MlpScratch, SparseMlp};
use crate::runtime::registry::DatasetMeta;
use crate::sketch::{QueryScratch, RaceSketch, SketchConfig};
use anyhow::Result;
use std::path::Path;

/// One point on a Figure-2 curve.
#[derive(Clone, Debug)]
pub struct CurvePoint {
    /// Memory reduction rate vs the teacher (x-axis, log scale).
    pub reduction: f64,
    /// Accuracy (cls) or MAE (reg) on the test split (y-axis).
    pub metric: f32,
}

/// All curves for one dataset panel.
#[derive(Clone, Debug)]
pub struct Panel {
    pub name: String,
    pub nn_metric: f32,
    pub nn_params: usize,
    pub rs: Vec<CurvePoint>,
    pub prune_one_time: Vec<CurvePoint>,
    pub prune_multi_time: Vec<CurvePoint>,
    pub kd: Vec<CurvePoint>,
}

/// Sketch-row ladder used for the RS curve.
pub const RS_ROW_LADDER: [usize; 7] = [50, 100, 200, 300, 500, 1000, 2000];
/// Pruning reduction levels trained by the python pipeline.
pub const PRUNE_REDUCTIONS: [usize; 7] = [2, 4, 8, 16, 32, 64, 128];
/// KD student widths trained by the python pipeline.
pub const KD_WIDTHS: [usize; 4] = [128, 48, 16, 6];

pub fn eval_panel(root: &Path, name: &str) -> Result<Panel> {
    let dir = root.join(name);
    let meta = DatasetMeta::load(&dir)?;
    let ds = Dataset::load_artifact(root, name, "test", meta.dim, meta.task)?;
    let teacher = Mlp::load(dir.join("nn_weights.bin"))?;
    let nn_params = teacher.param_count();
    let mut scratch = MlpScratch::default();
    let nn_preds: Vec<f32> = ds
        .rows()
        .map(|r| teacher.forward_with(r, &mut scratch))
        .collect();
    let nn_metric = ds.score(&nn_preds);

    // --- RS ladder -------------------------------------------------------
    let kp = KernelParams::load(dir.join("kernel_params.bin"))?;
    let mut rs = Vec::new();
    for rows in RS_ROW_LADDER {
        let sk = RaceSketch::build(
            &kp,
            &SketchConfig { rows, ..Default::default() },
        );
        let mut qs = QueryScratch::default();
        let preds: Vec<f32> =
            ds.rows().map(|r| sk.query_with(r, &mut qs)).collect();
        rs.push(CurvePoint {
            reduction: nn_params as f64 / sk.param_count() as f64,
            metric: ds.score(&preds),
        });
    }

    // --- pruning ----------------------------------------------------------
    let mut prune_one_time = Vec::new();
    let mut prune_multi_time = Vec::new();
    for red in PRUNE_REDUCTIONS {
        for (prefix, out) in [
            ("pruned_ot_r", &mut prune_one_time),
            ("pruned_mt_r", &mut prune_multi_time),
        ] {
            let path = dir.join(format!("{prefix}{red}.bin"));
            if !path.exists() {
                continue;
            }
            let dense = Mlp::load(&path)?;
            let sparse = SparseMlp::from_dense(&dense);
            let mut s = MlpScratch::default();
            let preds: Vec<f32> =
                ds.rows().map(|r| sparse.forward_with(r, &mut s)).collect();
            out.push(CurvePoint {
                reduction: nn_params as f64 / sparse.param_count() as f64,
                metric: ds.score(&preds),
            });
        }
    }

    // --- knowledge distillation -------------------------------------------
    let mut kd = Vec::new();
    for w in KD_WIDTHS {
        let path = dir.join(format!("kd_h{w}.bin"));
        if !path.exists() {
            continue;
        }
        let student = Mlp::load(&path)?;
        let mut s = MlpScratch::default();
        let preds: Vec<f32> =
            ds.rows().map(|r| student.forward_with(r, &mut s)).collect();
        kd.push(CurvePoint {
            reduction: nn_params as f64 / student.param_count() as f64,
            metric: ds.score(&preds),
        });
    }

    Ok(Panel {
        name: name.to_string(),
        nn_metric,
        nn_params,
        rs,
        prune_one_time,
        prune_multi_time,
        kd,
    })
}

fn fmt_curve(points: &[CurvePoint]) -> String {
    points
        .iter()
        .map(|p| format!("{:>7.1}x:{:>6.3}", p.reduction, p.metric))
        .collect::<Vec<_>>()
        .join("  ")
}

pub fn print_panel(panel: &Panel) {
    println!(
        "\n-- Figure 2 panel: {} (teacher metric {:.3}, {} params) --",
        panel.name, panel.nn_metric, panel.nn_params
    );
    println!("  {:<18} {}", "RS:", fmt_curve(&panel.rs));
    println!("  {:<18} {}", "One-Time Prune:",
             fmt_curve(&panel.prune_one_time));
    println!("  {:<18} {}", "Multi-Time Prune:",
             fmt_curve(&panel.prune_multi_time));
    println!("  {:<18} {}", "KD:", fmt_curve(&panel.kd));
}

pub fn to_csv(panels: &[Panel]) -> String {
    let mut out =
        String::from("dataset,method,memory_reduction,metric\n");
    for p in panels {
        let mut emit = |method: &str, pts: &[CurvePoint]| {
            for pt in pts {
                out.push_str(&format!(
                    "{},{},{:.3},{}\n",
                    p.name, method, pt.reduction, pt.metric
                ));
            }
        };
        emit("rs", &p.rs);
        emit("prune_one_time", &p.prune_one_time);
        emit("prune_multi_time", &p.prune_multi_time);
        emit("kd", &p.kd);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_emits_all_series() {
        let panel = Panel {
            name: "x".into(),
            nn_metric: 0.9,
            nn_params: 1000,
            rs: vec![CurvePoint { reduction: 10.0, metric: 0.89 }],
            prune_one_time: vec![CurvePoint { reduction: 2.0, metric: 0.9 }],
            prune_multi_time: vec![],
            kd: vec![CurvePoint { reduction: 5.0, metric: 0.85 }],
        };
        let csv = to_csv(&[panel]);
        assert!(csv.contains("x,rs,10.000,0.89"));
        assert!(csv.contains("x,prune_one_time,2.000,0.9"));
        assert!(csv.contains("x,kd,5.000,0.85"));
        assert!(!csv.contains("prune_multi_time,"));
    }
}
