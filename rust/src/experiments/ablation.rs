//! Ablations over the sketch's design choices (DESIGN.md §5):
//!
//! * estimator — median-of-means (paper Alg. 2 / Lemma 1) vs plain mean;
//! * debiasing — correcting the uniform 1/R rehash-collision floor
//!   (our implementation refinement over the paper) vs raw estimates;
//! * columns R — counter range vs accuracy;
//! * groups g — MoM group count.
//!
//! Each variant is evaluated on the full test split of one dataset at the
//! default L.

use crate::data::Dataset;
use crate::kernel::KernelParams;
use crate::runtime::registry::DatasetMeta;
use crate::sketch::{QueryScratch, RaceSketch, SketchConfig};
use anyhow::Result;
use std::path::Path;

#[derive(Clone, Debug)]
pub struct AblationRow {
    pub label: String,
    pub metric: f32,
    pub params: usize,
}

pub fn run(root: &Path, dataset: &str) -> Result<Vec<AblationRow>> {
    let dir = root.join(dataset);
    let meta = DatasetMeta::load(&dir)?;
    let kp = KernelParams::load(dir.join("kernel_params.bin"))?;
    let ds = Dataset::load_artifact(root, dataset, "test", meta.dim,
                                    meta.task)?;

    let eval = |cfg: &SketchConfig| -> (f32, usize) {
        let sk = RaceSketch::build(&kp, cfg);
        let mut s = QueryScratch::default();
        let preds: Vec<f32> =
            ds.rows().map(|r| sk.query_with(r, &mut s)).collect();
        (ds.score(&preds), sk.param_count())
    };

    let base = SketchConfig::default();
    let mut rows = Vec::new();
    let mut push = |label: &str, cfg: SketchConfig| {
        let (metric, params) = eval(&cfg);
        rows.push(AblationRow { label: label.to_string(), metric, params });
    };

    push("default (MoM g=8, debias, R=16)", base.clone());
    push("estimator: mean", SketchConfig { use_mom: false, ..base.clone() });
    push("debias: off", SketchConfig { debias: false, ..base.clone() });
    push(
        "debias: off + mean",
        SketchConfig { debias: false, use_mom: false, ..base.clone() },
    );
    for g in [2usize, 4, 16] {
        push(&format!("groups g={g}"),
             SketchConfig { groups: g, ..base.clone() });
    }
    for cols in [4usize, 8, 32, 64] {
        push(&format!("columns R={cols}"),
             SketchConfig { cols, ..base.clone() });
    }
    Ok(rows)
}

pub fn print_rows(dataset: &str, task_label: &str, rows: &[AblationRow]) {
    println!("\n== Ablation ({dataset}, metric = {task_label}) ==");
    println!("{:<36} {:>10} {:>10}", "variant", "metric", "params");
    println!("{}", "-".repeat(58));
    for r in rows {
        println!("{:<36} {:>10.4} {:>10}", r.label, r.metric, r.params);
    }
}
