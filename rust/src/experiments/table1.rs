//! Table 1: accuracy, memory and FLOPs for NN / Kernel / RS per dataset.
//!
//! All three models are evaluated in rust on the held-out test split; the
//! cost columns use the paper's §4.3 conventions (`metrics::cost`).

use crate::data::{Dataset, Task};
use crate::metrics::cost;
use crate::nn::MlpScratch;
use crate::runtime::registry::DatasetBundle;
use crate::sketch::QueryScratch;
use anyhow::Result;
use std::path::Path;

/// One measured Table-1 row.
#[derive(Clone, Debug)]
pub struct Table1Row {
    pub name: String,
    pub task: Task,
    /// [NN, Kernel, RS] — accuracy (cls) or MAE (reg).
    pub metric: [f32; 3],
    /// Parameter counts [NN, Kernel, RS].
    pub params: [usize; 3],
    /// FLOPs per query [NN, Kernel, RS].
    pub flops: [usize; 3],
}

impl Table1Row {
    pub fn mem_reduction(&self) -> f64 {
        self.params[0] as f64 / self.params[2] as f64
    }

    pub fn flops_reduction(&self) -> f64 {
        self.flops[0] as f64 / self.flops[2] as f64
    }
}

/// Evaluate one dataset bundle into a Table-1 row.
pub fn eval_dataset(root: &Path, bundle: &DatasetBundle) -> Result<Table1Row> {
    let meta = &bundle.meta;
    let ds = Dataset::load_artifact(root, &meta.name, "test", meta.dim,
                                    meta.task)?;
    let mut nn_scratch = MlpScratch::default();
    let nn_preds: Vec<f32> = ds
        .rows()
        .map(|r| bundle.mlp.forward_with(r, &mut nn_scratch))
        .collect();
    let kern_preds: Vec<f32> =
        ds.rows().map(|r| bundle.kernel.predict(r)).collect();
    let mut s = QueryScratch::default();
    let rs_preds: Vec<f32> =
        ds.rows().map(|r| bundle.sketch.query_with(r, &mut s)).collect();

    let kp = &bundle.kernel.params;
    Ok(Table1Row {
        name: meta.name.clone(),
        task: meta.task,
        metric: [
            ds.score(&nn_preds),
            ds.score(&kern_preds),
            ds.score(&rs_preds),
        ],
        params: [
            bundle.mlp.param_count(),
            kp.param_count(),
            bundle.sketch.param_count(),
        ],
        flops: [
            bundle.mlp.flops_per_query(),
            cost::kernel_model_flops(kp.d, kp.p, kp.m),
            bundle.sketch.flops_per_query(),
        ],
    })
}

/// Render the paper-style table, with the paper's own numbers inlined for
/// shape comparison.
pub fn print_table(rows: &[Table1Row]) {
    println!("\n== Table 1: accuracy / memory / FLOPs (measured) ==");
    println!(
        "{:<10} {:>8} {:>8} {:>8} | {:>9} {:>9} {:>6} | {:>9} {:>9} {:>6}",
        "dataset", "NN", "Kernel", "RS", "NN(MB)", "RS(MB)", "red.",
        "NN FLOPs", "RS FLOPs", "red."
    );
    println!("{}", "-".repeat(104));
    for r in rows {
        println!(
            "{:<10} {:>8.3} {:>8.3} {:>8.3} | {:>9} {:>9} {:>5.1}x | \
             {:>9} {:>9} {:>5.1}x",
            r.name,
            r.metric[0],
            r.metric[1],
            r.metric[2],
            cost::fmt_mb(r.params[0]),
            cost::fmt_mb(r.params[2]),
            r.mem_reduction(),
            cost::fmt_flops(r.flops[0]),
            cost::fmt_flops(r.flops[2]),
            r.flops_reduction(),
        );
    }
    println!("\n-- paper-reported values (for shape comparison) --");
    for p in &super::PAPER_TABLE1 {
        println!(
            "{:<10} {:>8.3} {:>8.3} {:>8.3} | {:>9.3} {:>9.3} {:>5.1}x | \
             {:>9} {:>9} {:>5.1}x",
            p.name, p.acc[0], p.acc[1], p.acc[2], p.mem_mb[0], p.mem_mb[1],
            p.mem_reduction, "-", "-", p.flops_reduction
        );
    }
}

/// CSV for downstream plotting.
pub fn to_csv(rows: &[Table1Row]) -> String {
    let mut out = String::from(
        "dataset,task,nn_metric,kernel_metric,rs_metric,nn_params,\
         kernel_params,rs_params,nn_flops,kernel_flops,rs_flops,\
         mem_reduction,flops_reduction\n",
    );
    for r in rows {
        out.push_str(&format!(
            "{},{:?},{},{},{},{},{},{},{},{},{},{:.2},{:.2}\n",
            r.name,
            r.task,
            r.metric[0],
            r.metric[1],
            r.metric[2],
            r.params[0],
            r.params[1],
            r.params[2],
            r.flops[0],
            r.flops[1],
            r.flops[2],
            r.mem_reduction(),
            r.flops_reduction(),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row() -> Table1Row {
        Table1Row {
            name: "t".into(),
            task: Task::Classification,
            metric: [0.9, 0.89, 0.88],
            params: [100_000, 5_000, 1_000],
            flops: [200_000, 10_000, 2_000],
        }
    }

    #[test]
    fn reductions() {
        let r = row();
        assert!((r.mem_reduction() - 100.0).abs() < 1e-9);
        assert!((r.flops_reduction() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn csv_has_header_and_row() {
        let csv = to_csv(&[row()]);
        let lines: Vec<&str> = csv.trim().split('\n').collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].starts_with("dataset,"));
        assert!(lines[1].starts_with("t,Classification,0.9,"));
    }
}
