//! Table 2: dataset information and parameter settings.

use crate::runtime::registry::DatasetMeta;

pub fn print_table(metas: &[DatasetMeta]) {
    println!("\n== Table 2: datasets and parameter settings ==");
    println!(
        "{:<10} {:<14} {:>5} {:>7} {:>7} {:<22} {:>6} {:>3} {:>5} {:>5}",
        "dataset", "task", "dim", "train", "test", "NN hidden", "L", "K",
        "R", "p"
    );
    println!("{}", "-".repeat(94));
    for m in metas {
        let hidden = m
            .hidden
            .iter()
            .map(|h| h.to_string())
            .collect::<Vec<_>>()
            .join("/");
        println!(
            "{:<10} {:<14} {:>5} {:>7} {:>7} {:<22} {:>6} {:>3} {:>5} {:>5}",
            m.name,
            format!("{:?}", m.task).to_lowercase(),
            m.dim,
            "-",
            "-",
            hidden,
            m.default_rows,
            m.k_per_row,
            m.default_cols,
            m.kernel_p,
        );
    }
    println!(
        "\n(L = sketch rows / hash repetitions, K = concatenation power, \
         R = counter columns, p = projected dim; paper Table 2 lists the \
         repetition count in its 'R' column — see DESIGN.md §4.)"
    );
}
