//! §3.2.1 theory check: median-of-means sketch error vs row count.
//!
//! Theorem 2 predicts |Z(q) − f_K(q)| = O(1/sqrt(L)).  We build sketches
//! at a ladder of L against one dataset's kernel model and report the
//! mean absolute error vs the exact KDE, plus the fitted decay exponent
//! (should be ≈ −0.5 until the debiased-rehash noise floor).

use crate::data::Dataset;
use crate::kernel::{KernelModel, KernelParams};
use crate::sketch::{QueryScratch, RaceSketch, SketchConfig};
use anyhow::Result;
use std::path::Path;

#[derive(Clone, Debug)]
pub struct TheoryPoint {
    pub rows: usize,
    pub mean_abs_err: f64,
    pub rel_err: f64,
}

pub const ROW_LADDER: [usize; 6] = [25, 50, 100, 400, 1600, 6400];

pub fn run(root: &Path, dataset: &str, n_queries: usize)
    -> Result<Vec<TheoryPoint>> {
    let dir = root.join(dataset);
    let kp = KernelParams::load(dir.join("kernel_params.bin"))?;
    let meta = crate::runtime::registry::DatasetMeta::load(&dir)?;
    let ds = Dataset::load_artifact(root, dataset, "test", meta.dim,
                                    meta.task)?;
    let model = KernelModel::new(kp.clone());
    let n = n_queries.min(ds.len());
    let exact: Vec<f32> =
        (0..n).map(|i| model.predict(ds.row(i))).collect();
    let scale = exact.iter().map(|v| v.abs() as f64).sum::<f64>()
        / n as f64;

    let mut out = Vec::new();
    for rows in ROW_LADDER {
        let sk = RaceSketch::build(
            &kp,
            &SketchConfig { rows, ..Default::default() },
        );
        let mut s = QueryScratch::default();
        let err: f64 = (0..n)
            .map(|i| {
                (sk.query_with(ds.row(i), &mut s) - exact[i]).abs() as f64
            })
            .sum::<f64>()
            / n as f64;
        out.push(TheoryPoint {
            rows,
            mean_abs_err: err,
            rel_err: err / scale.max(1e-9),
        });
    }
    Ok(out)
}

/// Least-squares slope of log(err) vs log(rows).
pub fn decay_exponent(points: &[TheoryPoint]) -> f64 {
    let n = points.len() as f64;
    let (mut sx, mut sy, mut sxx, mut sxy) = (0.0, 0.0, 0.0, 0.0);
    for p in points {
        let x = (p.rows as f64).ln();
        let y = p.mean_abs_err.max(1e-12).ln();
        sx += x;
        sy += y;
        sxx += x * x;
        sxy += x * y;
    }
    (n * sxy - sx * sy) / (n * sxx - sx * sx)
}

pub fn print_points(dataset: &str, points: &[TheoryPoint]) {
    println!("\n== Theory check ({dataset}): MoM error vs rows L ==");
    println!("{:>8} {:>14} {:>10}", "L", "mean |err|", "rel err");
    for p in points {
        println!("{:>8} {:>14.5} {:>9.1}%", p.rows, p.mean_abs_err,
                 p.rel_err * 100.0);
    }
    println!(
        "fitted decay exponent: {:.3}  (Theorem 2 predicts -0.5 until \
         the rehash noise floor)",
        decay_exponent(points)
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decay_exponent_recovers_slope() {
        // err = C * rows^-0.5 exactly -> slope -0.5.
        let pts: Vec<TheoryPoint> = [25usize, 100, 400, 1600]
            .iter()
            .map(|&rows| TheoryPoint {
                rows,
                mean_abs_err: 10.0 / (rows as f64).sqrt(),
                rel_err: 0.0,
            })
            .collect();
        assert!((decay_exponent(&pts) + 0.5).abs() < 1e-9);
    }

    #[test]
    fn decay_exponent_flat_is_zero() {
        let pts: Vec<TheoryPoint> = [10usize, 100, 1000]
            .iter()
            .map(|&rows| TheoryPoint {
                rows,
                mean_abs_err: 2.0,
                rel_err: 0.0,
            })
            .collect();
        assert!(decay_exponent(&pts).abs() < 1e-9);
    }
}
