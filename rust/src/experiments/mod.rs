//! Experiment harness — regenerates every table and figure of the paper's
//! evaluation (§4) from the artifacts tree.
//!
//! | exp | paper artifact | module |
//! |-----|----------------|--------|
//! | `table1` | Table 1: accuracy / memory / FLOPs, NN vs Kernel vs RS | [`table1`] |
//! | `table2` | Table 2: dataset + parameter inventory | [`table2`] |
//! | `figure2` | Figure 2(a–d): accuracy vs memory-reduction frontier vs pruning/KD | [`figure2`] |
//! | `theory` | §3.2.1 sanity: MoM error ~ 1/sqrt(L) | [`theory`] |
//!
//! Each module returns structured rows (testable) and offers a
//! `print_*` that renders the paper-style table to stdout.

pub mod ablation;
pub mod figure2;
pub mod table1;
pub mod table2;
pub mod theory;

/// Datasets in canonical paper order.
pub const DATASETS: [&str; 6] =
    ["adult", "phishing", "skin", "susy", "abalone", "yearmsd"];

/// The four datasets shown in Figure 2 panels (a)–(d).
pub const FIGURE2_DATASETS: [&str; 4] =
    ["adult", "phishing", "skin", "abalone"];

/// Paper-reported Table 1 values for side-by-side comparison
/// (accuracy columns: NN, Kernel, RS; memory MB: NN, RS).
pub struct PaperRow {
    pub name: &'static str,
    pub acc: [f64; 3],
    pub mem_mb: [f64; 2],
    pub mem_reduction: f64,
    pub flops_reduction: f64,
}

pub const PAPER_TABLE1: [PaperRow; 6] = [
    PaperRow { name: "adult", acc: [0.820, 0.829, 0.829],
               mem_mb: [1.82, 0.016], mem_reduction: 114.0,
               flops_reduction: 59.0 },
    PaperRow { name: "phishing", acc: [0.954, 0.954, 0.954],
               mem_mb: [1.60, 0.031], mem_reduction: 51.0,
               flops_reduction: 20.0 },
    PaperRow { name: "skin", acc: [0.999, 0.997, 0.997],
               mem_mb: [0.338, 0.019], mem_reduction: 17.8,
               flops_reduction: 11.0 },
    PaperRow { name: "susy", acc: [0.803, 0.802, 0.790],
               mem_mb: [5.73, 0.41], mem_reduction: 69.0,
               flops_reduction: 4.0 },
    PaperRow { name: "abalone", acc: [1.51, 1.52, 1.51],
               mem_mb: [0.28, 0.006], mem_reduction: 46.0,
               flops_reduction: 14.0 },
    PaperRow { name: "yearmsd", acc: [12.06, 12.05, 11.24],
               mem_mb: [6.25, 0.12], mem_reduction: 50.0,
               flops_reduction: 10.0 },
];
