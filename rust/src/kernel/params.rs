//! RSKP binary format — the distilled kernel model emitted by
//! `python/compile/binio.py::write_kernel_params`.  Layout (little-endian):
//!
//! ```text
//! magic b"RSKP" | u32 version
//! u32 d | u32 p | u32 m
//! f32 A[d*p] (row-major) | f32 X[m*p] (row-major) | f32 alpha[m]
//! f32 width | u64 lsh_seed | u32 k_per_row
//! u32 default_rows (L) | u32 default_cols (R)
//! ```

use anyhow::{bail, Context, Result};
use std::io::Read;
use std::path::Path;

/// Distilled kernel-model parameters (everything needed to evaluate the
/// exact `f_K` *and* to build a Representer Sketch of any size).
#[derive(Clone, Debug)]
pub struct KernelParams {
    /// Input dimensionality d.
    pub d: usize,
    /// Projected dimensionality p (asymmetric LSH, paper §4.3).
    pub p: usize,
    /// Number of representer points M.
    pub m: usize,
    /// Projection A, (d, p) row-major.
    pub a: Vec<f32>,
    /// Learned points X, (M, p) row-major.
    pub x: Vec<f32>,
    /// Learned weights α, (M,).
    pub alpha: Vec<f32>,
    /// LSH bucket width r.
    pub width: f32,
    /// Seed from which all hash functions are derived.
    pub lsh_seed: u64,
    /// Concatenation power K.
    pub k_per_row: u32,
    /// Default sketch rows L (Table-2 setting for this dataset).
    pub default_rows: usize,
    /// Default sketch columns R.
    pub default_cols: usize,
}

struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("truncated RSKP file at byte {}", self.i);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f32_vec(&mut self, n: usize) -> Result<Vec<f32>> {
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

impl KernelParams {
    pub fn input_dim(&self) -> usize {
        self.d
    }

    /// Parameter count under the paper's convention: sketch is separate;
    /// this is the *kernel model* cost (A + X + alpha).
    pub fn param_count(&self) -> usize {
        self.d * self.p + self.m * self.p + self.m
    }

    pub fn load<P: AsRef<Path>>(path: P) -> Result<Self> {
        let mut buf = Vec::new();
        std::fs::File::open(path.as_ref())
            .with_context(|| format!("open {:?}", path.as_ref()))?
            .read_to_end(&mut buf)?;
        Self::parse(&buf)
    }

    pub fn parse(buf: &[u8]) -> Result<Self> {
        if buf.len() < 8 || &buf[..4] != b"RSKP" {
            bail!("not an RSKP file");
        }
        let mut c = Cursor { b: buf, i: 4 };
        let version = c.u32()?;
        if version != 1 {
            bail!("unsupported RSKP version {version}");
        }
        let d = c.u32()? as usize;
        let p = c.u32()? as usize;
        let m = c.u32()? as usize;
        let a = c.f32_vec(d * p)?;
        let x = c.f32_vec(m * p)?;
        let alpha = c.f32_vec(m)?;
        let width = c.f32()?;
        let lsh_seed = c.u64()?;
        let k_per_row = c.u32()?;
        let default_rows = c.u32()? as usize;
        let default_cols = c.u32()? as usize;
        if c.i != buf.len() {
            bail!("trailing bytes in RSKP file");
        }
        if width <= 0.0 || k_per_row == 0 || default_cols < 2 {
            bail!("invalid RSKP parameters");
        }
        Ok(Self {
            d,
            p,
            m,
            a,
            x,
            alpha,
            width,
            lsh_seed,
            k_per_row,
            default_rows,
            default_cols,
        })
    }

    /// Serialize back to RSKP bytes (round-trip and rust-side authoring,
    /// e.g. examples that build their own kernel models).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(b"RSKP");
        out.extend_from_slice(&1u32.to_le_bytes());
        out.extend_from_slice(&(self.d as u32).to_le_bytes());
        out.extend_from_slice(&(self.p as u32).to_le_bytes());
        out.extend_from_slice(&(self.m as u32).to_le_bytes());
        for v in self.a.iter().chain(&self.x).chain(&self.alpha) {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out.extend_from_slice(&self.width.to_le_bytes());
        out.extend_from_slice(&self.lsh_seed.to_le_bytes());
        out.extend_from_slice(&self.k_per_row.to_le_bytes());
        out.extend_from_slice(&(self.default_rows as u32).to_le_bytes());
        out.extend_from_slice(&(self.default_cols as u32).to_le_bytes());
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> KernelParams {
        KernelParams {
            d: 3,
            p: 2,
            m: 2,
            a: vec![1., 2., 3., 4., 5., 6.],
            x: vec![0.1, 0.2, 0.3, 0.4],
            alpha: vec![0.5, -0.5],
            width: 2.5,
            lsh_seed: 0xDEAD_BEEF,
            k_per_row: 3,
            default_rows: 100,
            default_cols: 16,
        }
    }

    #[test]
    fn roundtrip() {
        let kp = sample();
        let bytes = kp.to_bytes();
        let kp2 = KernelParams::parse(&bytes).unwrap();
        assert_eq!(kp2.d, kp.d);
        assert_eq!(kp2.a, kp.a);
        assert_eq!(kp2.alpha, kp.alpha);
        assert_eq!(kp2.lsh_seed, kp.lsh_seed);
        assert_eq!(kp2.default_cols, kp.default_cols);
    }

    #[test]
    fn rejects_bad_magic() {
        let mut bytes = sample().to_bytes();
        bytes[0] = b'X';
        assert!(KernelParams::parse(&bytes).is_err());
    }

    #[test]
    fn rejects_truncation() {
        let bytes = sample().to_bytes();
        for cut in [5, 12, bytes.len() - 1] {
            assert!(KernelParams::parse(&bytes[..cut]).is_err(), "cut {cut}");
        }
    }

    #[test]
    fn rejects_trailing() {
        let mut bytes = sample().to_bytes();
        bytes.push(0);
        assert!(KernelParams::parse(&bytes).is_err());
    }
}
