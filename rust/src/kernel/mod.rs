//! The universal LSH kernel (paper §3.3) and the exact weighted kernel
//! density `f_K` (paper Eq. 3) — the "Kernel" column of Table 1.
//!
//! [`collision_prob`] is the closed-form L2-LSH collision probability of
//! Datar et al.; [`row_kernel`] raises it to the concatenation power K and
//! applies the 1/√3 distance scale of Achlioptas-sparse projections;
//! [`KernelParams`] loads the distilled model (`kernel_params.bin`,
//! RSKP format) and [`KernelModel`] evaluates `f_K` exactly in O(M·p).

pub mod params;

pub use params::KernelParams;

use crate::util::math::norm_cdf;

/// Distance scale for Achlioptas-sparse ±1 projections (entry variance
/// 1/3) relative to the unit-variance p-stable scheme.  See ref.py.
pub const SPARSE_SCALE: f64 = 0.577_350_269_189_625_8; // 1/sqrt(3)

/// Datar et al. L2-LSH collision probability `p(c)` for unit-variance
/// projections and bucket width `width`; `p(0) = 1`.
pub fn collision_prob(c: f64, width: f64) -> f64 {
    let c = c.max(1e-9);
    let t = width / c;
    let phi_neg = norm_cdf(-t);
    let tail = (2.0 / ((2.0 * std::f64::consts::PI).sqrt() * t))
        * (1.0 - (-0.5 * t * t).exp());
    (1.0 - 2.0 * phi_neg - tail).clamp(0.0, 1.0)
}

/// Effective kernel of one sketch row: K concatenated sparse hashes.
pub fn row_kernel(c: f64, width: f64, k_per_row: u32) -> f64 {
    collision_prob(c * SPARSE_SCALE, width).powi(k_per_row as i32)
}

/// The exact weighted-KDE model `f_K(q) = Σ_j α_j K(A^T q, x_j)`.
pub struct KernelModel {
    pub params: KernelParams,
}

impl KernelModel {
    pub fn new(params: KernelParams) -> Self {
        Self { params }
    }

    /// Project a query into the learned space: `q' = A^T q` (p floats).
    pub fn project(&self, q: &[f32], out: &mut [f32]) {
        let kp = &self.params;
        debug_assert_eq!(q.len(), kp.d);
        debug_assert_eq!(out.len(), kp.p);
        out.fill(0.0);
        // A is (d, p) row-major.
        for (i, &qi) in q.iter().enumerate() {
            if qi == 0.0 {
                continue;
            }
            let row = &kp.a[i * kp.p..(i + 1) * kp.p];
            for (o, &aij) in out.iter_mut().zip(row) {
                *o += qi * aij;
            }
        }
    }

    /// Exact `f_K` for a raw query (projects, then sums over M points).
    pub fn predict(&self, q: &[f32]) -> f32 {
        let mut proj = vec![0.0f32; self.params.p];
        self.project(q, &mut proj);
        self.predict_projected(&proj)
    }

    /// Exact `f_K` for an already-projected query.
    pub fn predict_projected(&self, proj: &[f32]) -> f32 {
        let kp = &self.params;
        let mut acc = 0.0f64;
        for j in 0..kp.m {
            let xj = &kp.x[j * kp.p..(j + 1) * kp.p];
            let mut d2 = 0.0f32;
            for (a, b) in proj.iter().zip(xj) {
                let diff = a - b;
                d2 += diff * diff;
            }
            let dist = (d2 as f64).sqrt();
            acc += kp.alpha[j] as f64
                * row_kernel(dist, kp.width as f64, kp.k_per_row);
        }
        acc as f32
    }

    /// Batch predict.
    pub fn predict_batch(&self, queries: &[Vec<f32>]) -> Vec<f32> {
        queries.iter().map(|q| self.predict(q)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collision_prob_monotone_and_bounded() {
        let mut prev = 1.0;
        for i in 1..200 {
            let c = i as f64 * 0.1;
            let p = collision_prob(c, 2.5);
            assert!((0.0..=1.0).contains(&p));
            assert!(p <= prev + 1e-12, "not monotone at c={c}");
            prev = p;
        }
    }

    #[test]
    fn collision_prob_limits() {
        assert!(collision_prob(1e-6, 2.0) > 0.999);
        assert!(collision_prob(1e4, 2.0) < 1e-3);
    }

    #[test]
    fn row_kernel_power() {
        let p1 = row_kernel(1.5, 2.0, 1);
        let p3 = row_kernel(1.5, 2.0, 3);
        assert!((p3 - p1.powi(3)).abs() < 1e-12);
    }

    #[test]
    fn kde_query_at_heavy_point() {
        // Single point with weight 3.5; querying at the point gives ~3.5.
        let kp = KernelParams {
            d: 2,
            p: 2,
            m: 1,
            a: vec![1.0, 0.0, 0.0, 1.0], // identity
            x: vec![0.4, -0.2],
            alpha: vec![3.5],
            width: 2.0,
            lsh_seed: 0,
            k_per_row: 2,
            default_rows: 10,
            default_cols: 8,
        };
        let model = KernelModel::new(kp);
        let v = model.predict(&[0.4, -0.2]);
        assert!((v - 3.5).abs() < 1e-4, "{v}");
    }

    #[test]
    fn kde_linear_in_alpha() {
        let mk = |alpha: Vec<f32>| {
            KernelModel::new(KernelParams {
                d: 3,
                p: 3,
                m: 2,
                a: vec![1., 0., 0., 0., 1., 0., 0., 0., 1.],
                x: vec![0.1, 0.2, 0.3, -0.5, 0.0, 0.5],
                alpha,
                width: 2.0,
                lsh_seed: 0,
                k_per_row: 1,
                default_rows: 4,
                default_cols: 4,
            })
        };
        let q = [0.2f32, -0.1, 0.4];
        let f1 = mk(vec![1.0, 0.0]).predict(&q);
        let f2 = mk(vec![0.0, 1.0]).predict(&q);
        let f12 = mk(vec![1.0, 1.0]).predict(&q);
        assert!((f1 + f2 - f12).abs() < 1e-5);
    }

    #[test]
    fn projection_is_matmul() {
        // A = [[1,2],[3,4],[5,6]] (d=3, p=2); q = [1, 1, 1] -> [9, 12].
        let kp = KernelParams {
            d: 3,
            p: 2,
            m: 0,
            a: vec![1., 2., 3., 4., 5., 6.],
            x: vec![],
            alpha: vec![],
            width: 1.0,
            lsh_seed: 0,
            k_per_row: 1,
            default_rows: 1,
            default_cols: 2,
        };
        let model = KernelModel::new(kp);
        let mut out = vec![0.0; 2];
        model.project(&[1.0, 1.0, 1.0], &mut out);
        assert_eq!(out, vec![9.0, 12.0]);
    }
}
