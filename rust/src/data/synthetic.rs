//! Rust-side synthetic workload generator — self-contained data for unit
//! tests, property tests, and benches that must not depend on `make
//! artifacts` having run.  (The *evaluation* datasets come from the python
//! pipeline; this generator mirrors its latent-signal recipe but does not
//! need to match it numerically.)

use super::{Dataset, Task};
use crate::util::rng::SplitMix64;

/// Configuration for a synthetic dataset.
#[derive(Clone, Debug)]
pub struct SyntheticSpec {
    pub n: usize,
    pub dim: usize,
    pub latent_dim: usize,
    pub task: Task,
    pub noise: f32,
    pub seed: u64,
}

impl Default for SyntheticSpec {
    fn default() -> Self {
        Self {
            n: 1000,
            dim: 10,
            latent_dim: 4,
            task: Task::Classification,
            noise: 0.2,
            seed: 7,
        }
    }
}

/// Generate a dataset: latent Gaussian code -> fixed random tanh net
/// signal; features are an affine view of the code plus noise.
pub fn generate(spec: &SyntheticSpec) -> Dataset {
    let mut rng = SplitMix64::new(spec.seed);
    let k = spec.latent_dim;
    // Random 2-layer tanh net over the latent code.
    let h1 = 16usize;
    let w1: Vec<f32> = (0..k * h1)
        .map(|_| rng.next_gaussian() as f32 * (1.2 / (k as f32).sqrt()))
        .collect();
    let w2: Vec<f32> = (0..h1)
        .map(|_| rng.next_gaussian() as f32 / (h1 as f32).sqrt())
        .collect();
    let view: Vec<f32> = (0..k * spec.dim)
        .map(|_| rng.next_gaussian() as f32 / (k as f32).sqrt())
        .collect();

    let mut x = Vec::with_capacity(spec.n * spec.dim);
    let mut signal = Vec::with_capacity(spec.n);
    for _ in 0..spec.n {
        let z: Vec<f32> = (0..k).map(|_| rng.next_gaussian() as f32).collect();
        // signal
        let mut s = 0.0f32;
        for j in 0..h1 {
            let mut a = 0.0f32;
            for i in 0..k {
                a += z[i] * w1[i * h1 + j];
            }
            s += a.tanh() * w2[j];
        }
        signal.push(s);
        // features
        for dcol in 0..spec.dim {
            let mut v = 0.0f32;
            for i in 0..k {
                v += z[i] * view[i * spec.dim + dcol];
            }
            x.push(v + spec.noise * rng.next_gaussian() as f32);
        }
    }
    // standardize signal
    let mean = signal.iter().sum::<f32>() / spec.n as f32;
    let var = signal.iter().map(|s| (s - mean) * (s - mean)).sum::<f32>()
        / spec.n as f32;
    let std = var.sqrt().max(1e-9);
    let y: Vec<f32> = signal
        .iter()
        .map(|s| {
            let v = (s - mean) / std
                + spec.noise * rng.next_gaussian() as f32;
            match spec.task {
                Task::Classification => (v > 0.0) as u32 as f32,
                Task::Regression => v,
            }
        })
        .collect();
    Dataset { dim: spec.dim, task: spec.task, x, y }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        let spec = SyntheticSpec::default();
        let a = generate(&spec);
        let b = generate(&spec);
        assert_eq!(a.len(), 1000);
        assert_eq!(a.x.len(), 1000 * 10);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn classification_labels_binary_balancedish() {
        let ds = generate(&SyntheticSpec { n: 4000, ..Default::default() });
        assert!(ds.y.iter().all(|&v| v == 0.0 || v == 1.0));
        let frac = ds.y.iter().sum::<f32>() / ds.len() as f32;
        assert!((0.25..0.75).contains(&frac), "{frac}");
    }

    #[test]
    fn regression_standardized() {
        let ds = generate(&SyntheticSpec {
            n: 5000,
            task: Task::Regression,
            noise: 0.1,
            ..Default::default()
        });
        let mean = ds.y.iter().sum::<f32>() / ds.len() as f32;
        assert!(mean.abs() < 0.1, "{mean}");
    }

    #[test]
    fn different_seeds_differ() {
        let a = generate(&SyntheticSpec::default());
        let b = generate(&SyntheticSpec { seed: 8, ..Default::default() });
        assert_ne!(a.x, b.x);
    }
}
