//! libsvm sparse text format parser.
//!
//! Each line: `<label> <index>:<value> <index>:<value> ...` with 1-based,
//! strictly increasing indices.  Classification labels `+1/-1` (or `1/0`)
//! map to `{1, 0}`; regression labels parse as floats.

use super::{Dataset, Task};
use anyhow::{bail, Context, Result};

pub fn parse_libsvm(text: &str, dim: usize, task: Task) -> Result<Dataset> {
    let mut x = Vec::new();
    let mut y = Vec::new();
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_ascii_whitespace();
        let label_tok = parts.next().context("missing label")?;
        let label = match task {
            Task::Classification => match label_tok {
                "+1" | "1" => 1.0,
                "-1" | "0" => 0.0,
                other => bail!("line {}: bad class label {other:?}", ln + 1),
            },
            Task::Regression => label_tok
                .parse::<f32>()
                .with_context(|| format!("line {}: bad label", ln + 1))?,
        };
        let row_start = x.len();
        x.resize(row_start + dim, 0.0);
        let mut prev_idx = 0usize;
        for feat in parts {
            let (idx_s, val_s) = feat
                .split_once(':')
                .with_context(|| format!("line {}: bad feature {feat:?}", ln + 1))?;
            let idx: usize = idx_s
                .parse()
                .with_context(|| format!("line {}: bad index", ln + 1))?;
            if idx == 0 || idx > dim {
                bail!("line {}: index {idx} out of range 1..={dim}", ln + 1);
            }
            if idx <= prev_idx {
                bail!("line {}: indices not increasing", ln + 1);
            }
            prev_idx = idx;
            let val: f32 = val_s
                .parse()
                .with_context(|| format!("line {}: bad value", ln + 1))?;
            x[row_start + idx - 1] = val;
        }
        y.push(label);
    }
    Ok(Dataset { dim, task, x, y })
}

/// Emit libsvm text (mirrors `datasets.py::write_libsvm`).
pub fn to_libsvm(ds: &Dataset) -> String {
    let mut out = String::new();
    for i in 0..ds.len() {
        match ds.task {
            Task::Classification => {
                out.push_str(if ds.y[i] > 0.5 { "+1" } else { "-1" });
            }
            Task::Regression => {
                out.push_str(&format!("{:.6}", ds.y[i]));
            }
        }
        for (j, &v) in ds.row(i).iter().enumerate() {
            if v != 0.0 {
                out.push_str(&format!(" {}:{:.6}", j + 1, v));
            }
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_classification() {
        let ds = parse_libsvm("+1 1:0.5 3:2\n-1 2:-1\n", 3,
                              Task::Classification).unwrap();
        assert_eq!(ds.len(), 2);
        assert_eq!(ds.row(0), &[0.5, 0.0, 2.0]);
        assert_eq!(ds.row(1), &[0.0, -1.0, 0.0]);
        assert_eq!(ds.y, vec![1.0, 0.0]);
    }

    #[test]
    fn parse_regression() {
        let ds =
            parse_libsvm("-0.25 1:1\n1.5 2:2\n", 2, Task::Regression).unwrap();
        assert_eq!(ds.y, vec![-0.25, 1.5]);
    }

    #[test]
    fn skips_blank_and_comment_lines() {
        let ds = parse_libsvm("\n# header\n+1 1:1\n\n", 1,
                              Task::Classification).unwrap();
        assert_eq!(ds.len(), 1);
    }

    #[test]
    fn rejects_out_of_range_index() {
        assert!(parse_libsvm("+1 4:1\n", 3, Task::Classification).is_err());
        assert!(parse_libsvm("+1 0:1\n", 3, Task::Classification).is_err());
    }

    #[test]
    fn rejects_non_increasing_indices() {
        assert!(
            parse_libsvm("+1 2:1 2:2\n", 3, Task::Classification).is_err()
        );
        assert!(
            parse_libsvm("+1 3:1 1:2\n", 3, Task::Classification).is_err()
        );
    }

    #[test]
    fn rejects_bad_label() {
        assert!(parse_libsvm("2 1:1\n", 1, Task::Classification).is_err());
        assert!(parse_libsvm("abc 1:1\n", 1, Task::Regression).is_err());
    }

    #[test]
    fn roundtrip() {
        let ds = parse_libsvm("+1 1:0.5 2:-2\n-1 3:1\n", 3,
                              Task::Classification).unwrap();
        let text = to_libsvm(&ds);
        let ds2 = parse_libsvm(&text, 3, Task::Classification).unwrap();
        assert_eq!(ds.x, ds2.x);
        assert_eq!(ds.y, ds2.y);
    }
}
