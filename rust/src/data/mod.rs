//! Data substrate: libsvm parsing, dataset containers, and a rust-side
//! synthetic generator for self-contained tests/benches.
//!
//! The artifacts pipeline materializes the paper's six datasets (or their
//! synthetic stand-ins — DESIGN.md §4) as standard libsvm text files, so
//! real UCI downloads drop in with no code change.

pub mod libsvm;
pub mod synthetic;

pub use libsvm::parse_libsvm;

/// Task type of a dataset (paper Table 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    Classification,
    Regression,
}

impl Task {
    pub fn from_str(s: &str) -> anyhow::Result<Task> {
        match s {
            "classification" => Ok(Task::Classification),
            "regression" => Ok(Task::Regression),
            other => anyhow::bail!("unknown task {other:?}"),
        }
    }
}

/// An in-memory dataset: dense rows + targets.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub dim: usize,
    pub task: Task,
    /// Row-major (n, dim).
    pub x: Vec<f32>,
    /// Targets: classification => {0, 1}; regression => float.
    pub y: Vec<f32>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.dim..(i + 1) * self.dim]
    }

    pub fn rows(&self) -> impl Iterator<Item = &[f32]> {
        self.x.chunks_exact(self.dim)
    }

    /// Load `artifacts/data/<name>/{train|test}.libsvm`.
    pub fn load_artifact(
        root: &std::path::Path,
        name: &str,
        split: &str,
        dim: usize,
        task: Task,
    ) -> anyhow::Result<Dataset> {
        let path = root.join("data").join(name).join(format!("{split}.libsvm"));
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("read {path:?}: {e}"))?;
        parse_libsvm(&text, dim, task)
    }

    /// Score predictions against targets: classification accuracy
    /// (logit > 0) or MAE.
    pub fn score(&self, preds: &[f32]) -> f32 {
        assert_eq!(preds.len(), self.len());
        match self.task {
            Task::Classification => {
                let correct = preds
                    .iter()
                    .zip(&self.y)
                    .filter(|(p, y)| (**p > 0.0) == (**y > 0.5))
                    .count();
                correct as f32 / self.len() as f32
            }
            Task::Regression => {
                preds
                    .iter()
                    .zip(&self.y)
                    .map(|(p, y)| (p - y).abs())
                    .sum::<f32>()
                    / self.len() as f32
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn score_classification() {
        let ds = Dataset {
            dim: 1,
            task: Task::Classification,
            x: vec![0.0; 4],
            y: vec![1.0, 0.0, 1.0, 0.0],
        };
        assert_eq!(ds.score(&[2.0, -1.0, -3.0, 0.5]), 0.5);
    }

    #[test]
    fn score_regression_mae() {
        let ds = Dataset {
            dim: 1,
            task: Task::Regression,
            x: vec![0.0; 2],
            y: vec![1.0, -1.0],
        };
        assert!((ds.score(&[2.0, -1.5]) - 0.75).abs() < 1e-6);
    }

    #[test]
    fn row_access() {
        let ds = Dataset {
            dim: 2,
            task: Task::Regression,
            x: vec![1.0, 2.0, 3.0, 4.0],
            y: vec![0.0, 0.0],
        };
        assert_eq!(ds.row(1), &[3.0, 4.0]);
        assert_eq!(ds.rows().count(), 2);
    }
}
