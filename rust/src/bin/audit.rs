//! `repsketch-audit` — the dependency-free static-analysis gate.
//!
//! Walks `rust/src/**`, enforces the invariants catalog in
//! [`repsketch::audit::rules`], prints `file:line: [rule] message` for
//! every violation, and exits non-zero if any rule fires.  CI runs this
//! as a hard gate; run it locally with
//!
//! ```text
//! cargo run --release --bin repsketch-audit
//! ```
//!
//! Options:
//!
//! * `--root PATH` — repo root to audit (default: walk up from the
//!   current directory until a `rust/src` tree is found).

use repsketch::audit;
use std::path::PathBuf;
use std::process::ExitCode;

fn find_root() -> Option<PathBuf> {
    // Prefer the compile-time manifest location (works under `cargo
    // run` from any cwd), then fall back to walking up from cwd (works
    // for a relocated binary).
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    if let Some(parent) = manifest.parent() {
        if parent.join("rust").join("src").is_dir() {
            return Some(parent.to_path_buf());
        }
    }
    let mut dir = std::env::current_dir().ok()?;
    loop {
        if dir.join("rust").join("src").is_dir() {
            return Some(dir);
        }
        if !dir.pop() {
            return None;
        }
    }
}

fn main() -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => root = args.next().map(PathBuf::from),
            "--help" | "-h" => {
                println!(
                    "repsketch-audit: dependency-free unsafe/atomics/syscall \
                     lint for rust/src/**\n\nusage: repsketch-audit \
                     [--root PATH]\n\nExits 0 when the tree is clean, 1 with \
                     file:line findings otherwise."
                );
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("repsketch-audit: unknown argument `{}`", other);
                return ExitCode::from(2);
            }
        }
    }
    let root = match root.or_else(find_root) {
        Some(r) => r,
        None => {
            eprintln!("repsketch-audit: no rust/src tree found; pass --root PATH");
            return ExitCode::from(2);
        }
    };
    match audit::audit_tree(&root) {
        Ok(findings) => {
            if findings.is_empty() {
                println!(
                    "repsketch-audit: clean ({} ok)",
                    root.join("rust/src").display()
                );
                ExitCode::SUCCESS
            } else {
                for f in &findings {
                    println!("{}", f);
                }
                eprintln!("repsketch-audit: {} violation(s)", findings.len());
                ExitCode::from(1)
            }
        }
        Err(e) => {
            eprintln!("repsketch-audit: {}", e);
            ExitCode::from(2)
        }
    }
}
