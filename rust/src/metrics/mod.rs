//! Cost accounting (paper §4.3 conventions), the energy model (Horowitz
//! ISSCC'14 numbers the paper cites), and latency histograms for the
//! serving layer.

pub mod cost;
pub mod energy;
pub mod latency;
pub mod slo;

pub use cost::{CostReport, MemoryUnit};
pub use energy::EnergyModel;
pub use latency::LatencyHistogram;
pub use slo::{LaneSlo, RemoteShardStats, ReplicaSlo, ShardSlo};
