//! Memory / FLOPs accounting under the paper's conventions (§4.3):
//!
//! * all parameters counted as 64-bit ("All integers and floating point
//!   numbers are stored in standard 64-bit");
//! * NN FLOPs: 2·out·in per dense layer (fvcore);
//! * RS FLOPs: `2 d p + p K L / 3 + L` (projection + sparse hashing +
//!   aggregation).  NOTE: the paper's formula writes `R` where its text
//!   says K·L hash functions exist; we follow the text (`L`) and expose
//!   the literal-`R` variant for comparison (DESIGN.md §4).

/// Bytes per parameter under the paper's convention.
pub const BYTES_PER_PARAM: usize = 8;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MemoryUnit {
    Params,
    Bytes,
    Mb,
}

/// A compared cost row (one model on one dataset).
#[derive(Clone, Debug)]
pub struct CostReport {
    pub name: String,
    pub params: usize,
    pub flops: usize,
}

impl CostReport {
    pub fn new(name: impl Into<String>, params: usize, flops: usize) -> Self {
        Self { name: name.into(), params, flops }
    }

    pub fn bytes(&self) -> usize {
        self.params * BYTES_PER_PARAM
    }

    pub fn mb(&self) -> f64 {
        self.bytes() as f64 / 1e6
    }

    /// Reduction factor of `self` relative to a baseline.
    pub fn memory_reduction_vs(&self, baseline: &CostReport) -> f64 {
        baseline.params as f64 / self.params.max(1) as f64
    }

    pub fn flops_reduction_vs(&self, baseline: &CostReport) -> f64 {
        baseline.flops as f64 / self.flops.max(1) as f64
    }
}

/// RS memory (params): counters + projection (paper: `L·R + d·p`).
pub fn rs_memory_params(rows: usize, cols: usize, d: usize, p: usize)
    -> usize {
    rows * cols + d * p
}

/// RS FLOPs per query, text-faithful variant (L hash rows):
/// `2 d p + p K L / 3 + L`.
pub fn rs_flops(d: usize, p: usize, k: usize, rows: usize) -> usize {
    2 * d * p + (p * k * rows) / 3 + rows
}

/// The paper's *literal* §4.3 formula (uses R where the text says L):
/// `2 d p + p K R / 3 + R`.
pub fn rs_flops_paper_literal(d: usize, p: usize, k: usize, r: usize)
    -> usize {
    rs_flops(d, p, k, r)
}

/// Exact-kernel-model FLOPs: projection + M distance/kernel evals.
/// Each distance is ~3p FLOPs; the closed-form kernel ~10 flops.
pub fn kernel_model_flops(d: usize, p: usize, m: usize) -> usize {
    2 * d * p + m * (3 * p + 10)
}

pub fn fmt_flops(f: usize) -> String {
    if f >= 100_000 {
        format!("{:.3}M", f as f64 / 1e6)
    } else if f >= 1_000 {
        format!("{:.2}K", f as f64 / 1e3)
    } else {
        format!("{f}")
    }
}

pub fn fmt_mb(params: usize) -> String {
    let mb = params as f64 * BYTES_PER_PARAM as f64 / 1e6;
    if mb >= 0.01 {
        format!("{mb:.3}")
    } else {
        format!("{mb:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reduction_factors() {
        let nn = CostReport::new("nn", 227_000, 454_000);
        let rs = CostReport::new("rs", 2_000, 4_000);
        assert!((rs.memory_reduction_vs(&nn) - 113.5).abs() < 0.1);
        assert!((rs.flops_reduction_vs(&nn) - 113.5).abs() < 0.1);
    }

    #[test]
    fn paper_adult_row_sanity() {
        // Adult (Table 1/2): d=123, p=8, K=1, L=500 → FLOPs ≈ 3.8K.
        let f = rs_flops(123, 8, 1, 500);
        assert!((3300..4500).contains(&f), "{f}");
        // memory with R=2 cols ≈ 2.0K params ≈ 0.016 MB.
        let m = rs_memory_params(500, 2, 123, 8);
        assert!((1900..2100).contains(&m), "{m}");
        assert_eq!(fmt_mb(m), "0.016");
    }

    #[test]
    fn bytes_convention() {
        assert_eq!(CostReport::new("x", 1000, 0).bytes(), 8000);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_flops(227_000), "0.227M");
        assert_eq!(fmt_flops(3_800), "3.80K");
        assert_eq!(fmt_flops(12), "12");
    }
}
