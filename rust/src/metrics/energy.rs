//! Energy model — quantifies the paper's §4.3 "Energy Requirement"
//! discussion with the numbers from its own source (Horowitz, ISSCC 2014,
//! 45 nm): DRAM access 1.3–2.6 nJ, cache access ~20 pJ per 64-bit word,
//! fp32 multiply 3.7 pJ, fp32 add 0.9 pJ, int add 0.1 pJ.
//!
//! The model charges every parameter read to DRAM when the working set
//! exceeds the cache budget and to cache otherwise — exactly the
//! phenomenon the paper exploits (the sketch fits in cache; the NN does
//! not).

/// Per-operation energy costs in picojoules (45 nm, Horowitz ISSCC'14).
#[derive(Clone, Debug)]
pub struct EnergyModel {
    pub fp_mul_pj: f64,
    pub fp_add_pj: f64,
    pub int_add_pj: f64,
    pub cache_access_pj: f64,
    pub dram_access_pj: f64,
    /// On-chip cache budget in bytes (default 2 MiB LLC slice).
    pub cache_bytes: usize,
}

impl Default for EnergyModel {
    fn default() -> Self {
        Self {
            fp_mul_pj: 3.7,
            fp_add_pj: 0.9,
            int_add_pj: 0.1,
            cache_access_pj: 20.0,
            dram_access_pj: 1950.0, // midpoint of 1.3–2.6 nJ
            cache_bytes: 2 << 20,
        }
    }
}

/// Breakdown of one inference's estimated energy.
#[derive(Clone, Debug)]
pub struct EnergyEstimate {
    pub compute_pj: f64,
    pub memory_pj: f64,
}

impl EnergyEstimate {
    pub fn total_pj(&self) -> f64 {
        self.compute_pj + self.memory_pj
    }

    pub fn total_nj(&self) -> f64 {
        self.total_pj() / 1e3
    }
}

impl EnergyModel {
    /// Whether a model of `param_bytes` working set is cache-resident.
    pub fn cache_resident(&self, param_bytes: usize) -> bool {
        param_bytes <= self.cache_bytes
    }

    /// Energy for a dense NN forward: `muls` fp multiplies, `adds` fp
    /// adds, and one parameter read per weight/bias.
    pub fn nn_inference(&self, params: usize, muls: usize, adds: usize)
        -> EnergyEstimate {
        let per_access = if self.cache_resident(params * 8) {
            self.cache_access_pj
        } else {
            self.dram_access_pj
        };
        EnergyEstimate {
            compute_pj: muls as f64 * self.fp_mul_pj
                + adds as f64 * self.fp_add_pj,
            memory_pj: params as f64 * per_access,
        }
    }

    /// Energy for a Representer-Sketch query: the projection (d·p
    /// mul-adds), sparse hashing (`p·K·L/3` adds/subs), L counter reads
    /// plus projection reads, from cache if resident.
    pub fn sketch_inference(
        &self,
        d: usize,
        p: usize,
        k: usize,
        rows: usize,
        cols: usize,
    ) -> EnergyEstimate {
        let proj_muls = d * p;
        let proj_adds = d * p;
        let hash_adds = p * k * rows / 3;
        let agg_adds = rows;
        let param_bytes = (rows * cols + d * p) * 8;
        let per_access = if self.cache_resident(param_bytes) {
            self.cache_access_pj
        } else {
            self.dram_access_pj
        };
        // reads: projection matrix once + L counters + hash metadata
        let accesses = d * p + rows + p * k * rows / 3;
        EnergyEstimate {
            compute_pj: proj_muls as f64 * self.fp_mul_pj
                + (proj_adds + hash_adds + agg_adds) as f64 * self.fp_add_pj,
            memory_pj: accesses as f64 * per_access,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dram_at_least_65x_cache() {
        // The paper's §1 claim: DRAM ≥ 65× a cache fetch.
        let m = EnergyModel::default();
        assert!(m.dram_access_pj / m.cache_access_pj >= 65.0);
    }

    #[test]
    fn mul_about_4x_add() {
        let m = EnergyModel::default();
        let ratio = m.fp_mul_pj / m.fp_add_pj;
        assert!((3.0..5.0).contains(&ratio));
    }

    #[test]
    fn big_nn_pays_dram_small_sketch_does_not() {
        let m = EnergyModel::default();
        // adult teacher: 227K params (1.8 MB at f64) — resident in 2 MiB?
        // 227e3*8 = 1.82 MB < 2 MiB: borderline resident; SUSY (716K,
        // 5.7MB) is not.
        assert!(!m.cache_resident(716_000 * 8));
        assert!(m.cache_resident(2_000 * 8));
    }

    #[test]
    fn sketch_energy_far_below_nn() {
        let m = EnergyModel::default();
        // SUSY-scale NN vs its sketch.
        let nn = m.nn_inference(716_000, 715_000, 715_000);
        let rs = m.sketch_inference(18, 10, 2, 1000, 16);
        assert!(nn.total_pj() / rs.total_pj() > 100.0);
    }

    #[test]
    fn estimate_components_positive() {
        let m = EnergyModel::default();
        let e = m.sketch_inference(10, 5, 1, 100, 8);
        assert!(e.compute_pj > 0.0 && e.memory_pj > 0.0);
        assert!(e.total_nj() > 0.0);
    }
}
