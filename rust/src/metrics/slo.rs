//! SLO accounting for the serving plane: success/error counters plus
//! latency quantiles, accumulated lock-free (atomics only) on the lane
//! thread and read out by the `stats` wire verb.
//!
//! ## The error-budget convention
//!
//! A lane's availability objective is expressed as a target success
//! fraction (e.g. `0.999`).  Over any window, the **error budget** is
//! `total_requests × (1 - target)`; [`LaneSlo::budget_remaining`]
//! returns how many more errors the lane may serve before the
//! objective is violated (negative = already blown).  The counters are
//! monotonic for the process lifetime — operators diff successive
//! `stats` snapshots to get windowed budgets, the same way Prometheus
//! counters are consumed.
//!
//! Three granularities, one file:
//!
//! * [`LaneSlo`] — per (model, backend) lane on the inference plane
//!   (also reused by the shard plane's `ShardService` for its kernel
//!   counters: `ok` = means served, `errors` = error lines answered).
//! * [`ShardSlo`] — per shard of a remote set: gather outcomes plus the
//!   replication machinery's own counters (hedges, failovers,
//!   reconnect probes, quarantines, discarded duplicates).
//! * [`ReplicaSlo`] — per replica address: exchanges sent / won /
//!   abandoned, plus the EWMA latency estimate the hedging deadline is
//!   seeded from.
//!
//! [`RemoteShardStats`] aggregates the latter two for one remote shard
//! set; `coordinator::Router` holds one per registered remote lane and
//! serializes the whole tree for the `stats` verb.

use super::latency::LatencyHistogram;
use crate::util::json::{self, Json};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Latency quantiles as a JSON object — the shared rendering for every
/// histogram the `stats` verb exposes.
pub fn histogram_json(h: &LatencyHistogram) -> Json {
    json::obj(vec![
        ("n", Json::from_u64(h.count())),
        ("mean_us", Json::num(h.mean_ns() / 1e3)),
        ("p50_us", Json::num(h.quantile_ns(0.5) / 1e3)),
        ("p99_us", Json::num(h.quantile_ns(0.99) / 1e3)),
        ("p999_us", Json::num(h.quantile_ns(0.999) / 1e3)),
    ])
}

/// Per-lane SLO counters: one success counter, one error counter, one
/// latency histogram.  All atomic — recorded from the lane worker
/// thread without locks, read from anywhere.
#[derive(Debug, Default)]
pub struct LaneSlo {
    pub ok: AtomicU64,
    pub errors: AtomicU64,
    pub latency: LatencyHistogram,
}

impl LaneSlo {
    pub fn new() -> LaneSlo {
        LaneSlo::default()
    }

    /// One successfully answered request.
    pub fn record_ok(&self, dur: std::time::Duration) {
        // ORDERING: Relaxed — independent monotonic stat counter; no
        // other memory is published through it.
        self.ok.fetch_add(1, Ordering::Relaxed);
        self.latency.record(dur);
    }

    /// One request answered with an error.
    pub fn record_error(&self) {
        // ORDERING: Relaxed — independent monotonic stat counter.
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn ok_count(&self) -> u64 {
        // ORDERING: Relaxed — monotonic stat read; snapshots may lag.
        self.ok.load(Ordering::Relaxed)
    }

    pub fn error_count(&self) -> u64 {
        // ORDERING: Relaxed — monotonic stat read; snapshots may lag.
        self.errors.load(Ordering::Relaxed)
    }

    /// Errors this lane may still serve before an availability target
    /// (a success fraction like `0.999`) is violated over the counters'
    /// lifetime window.  Negative: the budget is already blown.
    pub fn budget_remaining(&self, target: f64) -> i64 {
        let ok = self.ok_count();
        let errors = self.error_count();
        let total = (ok + errors) as f64;
        (total * (1.0 - target)).floor() as i64 - errors as i64
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("ok", Json::from_u64(self.ok_count())),
            ("errors", Json::from_u64(self.error_count())),
            ("latency", histogram_json(&self.latency)),
        ])
    }
}

/// Wire-level reject counters for one reactor listener: how often the
/// framing layer itself refused input or output before any service
/// logic ran.  Shared `Arc` between the reactor (writer, via
/// `NetOptions`) and the owning service's `stats` verb (reader).
///
/// * `oversize_lines` — JSON lines over the line cap, discarded while
///   streaming (answered with an id-correlated error).
/// * `oversize_frames` — binary frames whose declared payload length
///   exceeded the frame cap (payload discarded byte-exactly, answered
///   with an error frame; connection survives).
/// * `bad_headers` — corrupt frame headers (bad magic/version/reserved
///   bytes); answered once, then the connection is closed because the
///   stream cannot be resynchronized.
/// * `write_refused` — single responses too large to ever fit under
///   the write cap, refused with a per-request error instead of
///   tearing the connection down.
#[derive(Debug, Default)]
pub struct FrameSlo {
    pub oversize_lines: AtomicU64,
    pub oversize_frames: AtomicU64,
    pub bad_headers: AtomicU64,
    pub write_refused: AtomicU64,
}

impl FrameSlo {
    pub fn new() -> FrameSlo {
        FrameSlo::default()
    }

    pub fn inc_oversize_line(&self) {
        // ORDERING: Relaxed — independent monotonic stat counter.
        self.oversize_lines.fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_oversize_frame(&self) {
        // ORDERING: Relaxed — independent monotonic stat counter.
        self.oversize_frames.fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_bad_header(&self) {
        // ORDERING: Relaxed — independent monotonic stat counter.
        self.bad_headers.fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_write_refused(&self) {
        // ORDERING: Relaxed — independent monotonic stat counter.
        self.write_refused.fetch_add(1, Ordering::Relaxed);
    }

    pub fn to_json(&self) -> Json {
        // ORDERING: Relaxed — stats-verb snapshot of monotonic
        // counters; exactness across counters is not promised.
        let c = |a: &AtomicU64| Json::from_u64(a.load(Ordering::Relaxed));
        json::obj(vec![
            ("oversize_lines", c(&self.oversize_lines)),
            ("oversize_frames", c(&self.oversize_frames)),
            ("bad_headers", c(&self.bad_headers)),
            ("write_refused", c(&self.write_refused)),
        ])
    }
}

/// Counter-plane mutation accounting for one lane or shard: how many
/// `update`s were applied, how many epoch publishes made them visible,
/// and how stale the oldest unpublished delta currently is.  The
/// staleness bound the plane guarantees is
/// `pending <= sketch::epoch::MAX_PENDING` (a publish is forced past
/// it) AND read-your-writes in lane order (every query eval publishes
/// pending deltas first), so `staleness_us` only grows while no query
/// or explicit publish arrives — surfaced here so operators can see an
/// idle-but-dirty plane.
#[derive(Debug, Default)]
pub struct UpdateSlo {
    /// Updates applied (monotonic).
    pub updates: AtomicU64,
    /// Epoch publishes (monotonic).
    pub publishes: AtomicU64,
    /// Deltas applied to the shadow buffer but not yet published.
    pub pending: AtomicU64,
    /// The published epoch readers currently pin.
    pub epoch: AtomicU64,
    /// When the oldest currently-pending delta was applied.
    pending_since: Mutex<Option<Instant>>,
}

impl UpdateSlo {
    pub fn new() -> UpdateSlo {
        UpdateSlo::default()
    }

    /// One delta applied to the shadow plane; `pending_now` is the new
    /// unpublished-delta count.
    pub fn record_update(&self, pending_now: u64) {
        // ORDERING: Relaxed on both — advisory stat mirrors of state
        // the plane's writer mutex already serializes; readers (stats
        // verb, publish fast path) tolerate lag and re-check under the
        // mutex before acting.
        self.updates.fetch_add(1, Ordering::Relaxed);
        self.pending.store(pending_now, Ordering::Relaxed); // ORDERING: see above
        let mut since = self.pending_since.lock().unwrap();
        if since.is_none() {
            *since = Some(Instant::now());
        }
    }

    /// An epoch flip made every pending delta reader-visible.
    pub fn record_publish(&self, epoch: u64) {
        // ORDERING: Relaxed on all three — advisory stat mirrors; the
        // authoritative epoch is CounterPlane's Release/Acquire atomic,
        // these only feed the stats verb.
        self.publishes.fetch_add(1, Ordering::Relaxed);
        self.pending.store(0, Ordering::Relaxed); // ORDERING: see above
        self.epoch.store(epoch, Ordering::Relaxed); // ORDERING: see above
        *self.pending_since.lock().unwrap() = None;
    }

    /// Age of the oldest unpublished delta in microseconds (0.0 when
    /// the plane is clean).
    pub fn staleness_us(&self) -> f64 {
        match *self.pending_since.lock().unwrap() {
            Some(t) => t.elapsed().as_nanos() as f64 / 1e3,
            None => 0.0,
        }
    }

    pub fn to_json(&self) -> Json {
        // ORDERING: Relaxed — stats-verb snapshot of monotonic
        // counters; exactness across counters is not promised.
        let c = |a: &AtomicU64| Json::from_u64(a.load(Ordering::Relaxed));
        json::obj(vec![
            ("epoch", c(&self.epoch)),
            ("updates", c(&self.updates)),
            ("publishes", c(&self.publishes)),
            ("pending", c(&self.pending)),
            ("staleness_us", Json::num(self.staleness_us())),
        ])
    }
}

/// Per-shard counters for one remote shard set.  `gathers`/`errors`
/// count batch outcomes attributed to this shard; the rest count the
/// replication machinery itself.
#[derive(Debug, Default)]
pub struct ShardSlo {
    /// Accepted answers (one per successful gather of this shard).
    pub gathers: AtomicU64,
    /// Batch failures attributed to this shard.
    pub errors: AtomicU64,
    /// Hedge requests issued to a second replica.
    pub hedges: AtomicU64,
    /// In-batch failovers (a replica died mid-gather and another took
    /// over the same request id) plus scatter-time replica swaps.
    pub failovers: AtomicU64,
    /// Dial attempts to a disconnected replica (backoff-gated).
    pub reconnects: AtomicU64,
    /// Replicas quarantined after a failure.
    pub quarantines: AtomicU64,
    /// Late/duplicate answers discarded by request id.
    pub discarded: AtomicU64,
    /// Latency of accepted answers (send → accept on the lane thread).
    pub latency: LatencyHistogram,
}

impl ShardSlo {
    pub fn to_json(&self) -> Json {
        // ORDERING: Relaxed — stats-verb snapshot of monotonic
        // counters; exactness across counters is not promised.
        let c = |a: &AtomicU64| Json::from_u64(a.load(Ordering::Relaxed));
        json::obj(vec![
            ("gathers", c(&self.gathers)),
            ("errors", c(&self.errors)),
            ("hedges", c(&self.hedges)),
            ("failovers", c(&self.failovers)),
            ("reconnects", c(&self.reconnects)),
            ("quarantines", c(&self.quarantines)),
            ("discarded", c(&self.discarded)),
            ("latency", histogram_json(&self.latency)),
        ])
    }
}

/// Per-replica counters: exchange accounting plus the EWMA latency
/// estimate (microseconds, stored as f64 bits so updates stay a single
/// atomic store on the lane thread).
#[derive(Debug)]
pub struct ReplicaSlo {
    pub addr: String,
    /// Requests written to this replica.
    pub sent: AtomicU64,
    /// Answers accepted (this replica won the exchange).
    pub answered: AtomicU64,
    /// Exchanges abandoned: lost a hedge race, failed over, or timed
    /// out.  Abandoned exchanges never update `ewma_us`.
    pub abandoned: AtomicU64,
    ewma_us_bits: AtomicU64,
}

impl ReplicaSlo {
    pub fn new(addr: &str) -> ReplicaSlo {
        ReplicaSlo {
            addr: addr.to_string(),
            sent: AtomicU64::new(0),
            answered: AtomicU64::new(0),
            abandoned: AtomicU64::new(0),
            ewma_us_bits: AtomicU64::new(0.0f64.to_bits()),
        }
    }

    /// EWMA latency estimate in microseconds; `0.0` = no samples yet.
    pub fn ewma_us(&self) -> f64 {
        // ORDERING: Relaxed — single-word advisory estimate, written
        // and mostly read on the lane thread; a stale read only skews a
        // hedging deadline marginally.
        f64::from_bits(self.ewma_us_bits.load(Ordering::Relaxed))
    }

    pub fn set_ewma_us(&self, v: f64) {
        // ORDERING: Relaxed — see ewma_us.
        self.ewma_us_bits.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn to_json(&self) -> Json {
        // ORDERING: Relaxed — stats-verb snapshot of monotonic
        // counters; exactness across counters is not promised.
        let c = |a: &AtomicU64| Json::from_u64(a.load(Ordering::Relaxed));
        json::obj(vec![
            ("addr", Json::Str(self.addr.clone())),
            ("sent", c(&self.sent)),
            ("answered", c(&self.answered)),
            ("abandoned", c(&self.abandoned)),
            ("ewma_us", Json::num(self.ewma_us())),
        ])
    }
}

/// The whole observability surface of one remote shard set: per-shard
/// counters plus the flat replica table, `Arc`-shared between the lane
/// engine (writer) and the router's `stats` verb (reader).
#[derive(Debug)]
pub struct RemoteShardStats {
    pub shards: Vec<ShardSlo>,
    pub replicas: Vec<ReplicaSlo>,
    /// Replica indices (into `replicas`) per shard.
    pub groups: Vec<Vec<usize>>,
}

impl RemoteShardStats {
    pub fn new(replica_addrs_per_shard: &[Vec<String>])
        -> RemoteShardStats {
        let mut replicas = Vec::new();
        let mut groups = Vec::new();
        for group in replica_addrs_per_shard {
            let mut idx = Vec::with_capacity(group.len());
            for addr in group {
                idx.push(replicas.len());
                replicas.push(ReplicaSlo::new(addr));
            }
            groups.push(idx);
        }
        RemoteShardStats {
            shards: replica_addrs_per_shard
                .iter()
                .map(|_| ShardSlo::default())
                .collect(),
            replicas,
            groups,
        }
    }

    /// One JSON object per shard, replicas nested in group order.
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.shards
                .iter()
                .enumerate()
                .map(|(s, slo)| {
                    let c = |a: &AtomicU64| {
                        // ORDERING: Relaxed — stats-verb snapshot of
                        // monotonic counters.
                        Json::from_u64(a.load(Ordering::Relaxed))
                    };
                    json::obj(vec![
                        ("shard", Json::from_u64(s as u64)),
                        ("gathers", c(&slo.gathers)),
                        ("errors", c(&slo.errors)),
                        ("hedges", c(&slo.hedges)),
                        ("failovers", c(&slo.failovers)),
                        ("reconnects", c(&slo.reconnects)),
                        ("quarantines", c(&slo.quarantines)),
                        ("discarded", c(&slo.discarded)),
                        ("latency", histogram_json(&slo.latency)),
                        (
                            "replicas",
                            Json::Arr(
                                self.groups[s]
                                    .iter()
                                    .map(|&r| self.replicas[r].to_json())
                                    .collect(),
                            ),
                        ),
                    ])
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn budget_arithmetic() {
        let slo = LaneSlo::new();
        for _ in 0..999 {
            slo.record_ok(Duration::from_micros(100));
        }
        slo.record_error();
        // 1000 requests at a 99.9% target: budget is exactly 1 error,
        // exactly 1 spent.
        assert_eq!(slo.budget_remaining(0.999), 0);
        slo.record_error();
        assert!(slo.budget_remaining(0.999) < 0);
        // A lax target leaves room.
        assert!(slo.budget_remaining(0.9) > 0);
    }

    #[test]
    fn lane_slo_json_shape() {
        let slo = LaneSlo::new();
        slo.record_ok(Duration::from_micros(50));
        slo.record_error();
        let j = slo.to_json();
        assert_eq!(j.get("ok").unwrap().as_u64(), Some(1));
        assert_eq!(j.get("errors").unwrap().as_u64(), Some(1));
        let lat = j.get("latency").unwrap();
        assert_eq!(lat.get("n").unwrap().as_u64(), Some(1));
        assert!(lat.get("p999_us").unwrap().as_f64().unwrap() > 0.0);
        // The line must be real JSON end to end.
        let reparsed = json::parse(&j.to_string()).unwrap();
        assert_eq!(reparsed.get("ok").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn remote_stats_json_groups_replicas_per_shard() {
        let stats = RemoteShardStats::new(&[
            vec!["a0".to_string(), "a1".to_string()],
            vec!["b0".to_string()],
        ]);
        assert_eq!(stats.shards.len(), 2);
        assert_eq!(stats.replicas.len(), 3);
        assert_eq!(stats.groups, vec![vec![0, 1], vec![2]]);
        stats.shards[1]
            .hedges
            .fetch_add(3, Ordering::Relaxed);
        stats.replicas[2].set_ewma_us(123.5);
        let j = stats.to_json();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[1].get("hedges").unwrap().as_u64(), Some(3));
        let reps = arr[1].get("replicas").unwrap().as_arr().unwrap();
        assert_eq!(reps.len(), 1);
        assert_eq!(reps[0].get("addr").unwrap().as_str(), Some("b0"));
        assert_eq!(
            reps[0].get("ewma_us").unwrap().as_f64(),
            Some(123.5)
        );
    }

    #[test]
    fn update_slo_tracks_pending_and_staleness() {
        let u = UpdateSlo::new();
        assert_eq!(u.staleness_us(), 0.0);
        u.record_update(1);
        u.record_update(2);
        std::thread::sleep(Duration::from_millis(2));
        assert!(u.staleness_us() > 0.0, "dirty plane must age");
        assert_eq!(u.pending.load(Ordering::Relaxed), 2);
        u.record_publish(1);
        assert_eq!(u.pending.load(Ordering::Relaxed), 0);
        assert_eq!(u.staleness_us(), 0.0);
        let j = u.to_json();
        assert_eq!(j.get("epoch").unwrap().as_u64(), Some(1));
        assert_eq!(j.get("updates").unwrap().as_u64(), Some(2));
        assert_eq!(j.get("publishes").unwrap().as_u64(), Some(1));
        assert_eq!(j.get("pending").unwrap().as_u64(), Some(0));
    }

    #[test]
    fn ewma_roundtrips_through_bits() {
        let r = ReplicaSlo::new("x");
        assert_eq!(r.ewma_us(), 0.0);
        r.set_ewma_us(42.25);
        assert_eq!(r.ewma_us(), 42.25);
    }

    #[test]
    fn frame_slo_counts_and_serializes() {
        let f = FrameSlo::new();
        f.inc_oversize_line();
        f.inc_oversize_frame();
        f.inc_oversize_frame();
        f.inc_bad_header();
        f.inc_write_refused();
        let j = f.to_json();
        assert_eq!(j.get("oversize_lines").unwrap().as_u64(), Some(1));
        assert_eq!(j.get("oversize_frames").unwrap().as_u64(), Some(2));
        assert_eq!(j.get("bad_headers").unwrap().as_u64(), Some(1));
        assert_eq!(j.get("write_refused").unwrap().as_u64(), Some(1));
        let reparsed = json::parse(&j.to_string()).unwrap();
        assert_eq!(
            reparsed.get("oversize_frames").unwrap().as_u64(),
            Some(2)
        );
    }
}
