//! Lock-free-ish latency histogram for the serving layer: log-spaced
//! buckets from 100 ns to ~100 s, atomic counters, quantile readout.

use std::sync::atomic::{AtomicU64, Ordering};

const BUCKETS: usize = 64;
const BASE_NS: f64 = 100.0;
/// Geometric growth chosen so bucket 63 ≈ 134 s.
const GROWTH: f64 = 1.39;

/// Histogram of durations.
#[derive(Debug)]
pub struct LatencyHistogram {
    counts: [AtomicU64; BUCKETS],
    sum_ns: AtomicU64,
    n: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_ns: AtomicU64::new(0),
            n: AtomicU64::new(0),
        }
    }

    fn bucket_for(ns: u64) -> usize {
        if ns as f64 <= BASE_NS {
            return 0;
        }
        let b = ((ns as f64 / BASE_NS).ln() / GROWTH.ln()).floor() as usize;
        b.min(BUCKETS - 1)
    }

    /// Upper bound (ns) of bucket `i`.
    fn bucket_upper(i: usize) -> f64 {
        BASE_NS * GROWTH.powi(i as i32 + 1)
    }

    pub fn record(&self, dur: std::time::Duration) {
        self.record_ns(dur.as_nanos() as u64);
    }

    pub fn record_ns(&self, ns: u64) {
        // ORDERING: Relaxed on all three — independent monotonic stat
        // counters; a reader racing a record may see a sample in one
        // counter and not the others, which quantile/mean readout
        // tolerates by construction (approximate by design).
        self.counts[Self::bucket_for(ns)].fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed); // ORDERING: see above
        self.n.fetch_add(1, Ordering::Relaxed); // ORDERING: see above
    }

    pub fn count(&self) -> u64 {
        // ORDERING: Relaxed — monotonic stat read; snapshots may lag.
        self.n.load(Ordering::Relaxed)
    }

    pub fn mean_ns(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        // ORDERING: Relaxed — stat read paired only with count above.
        self.sum_ns.load(Ordering::Relaxed) as f64 / n as f64
    }

    /// Approximate quantile (bucket upper bound).
    pub fn quantile_ns(&self, q: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let target = (q * n as f64).ceil() as u64;
        let mut acc = 0u64;
        for i in 0..BUCKETS {
            // ORDERING: Relaxed — approximate quantile readout.
            acc += self.counts[i].load(Ordering::Relaxed);
            if acc >= target {
                return Self::bucket_upper(i);
            }
        }
        Self::bucket_upper(BUCKETS - 1)
    }

    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.1}us p50={:.1}us p99={:.1}us",
            self.count(),
            self.mean_ns() / 1e3,
            self.quantile_ns(0.5) / 1e3,
            self.quantile_ns(0.99) / 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean_ns(), 0.0);
        assert_eq!(h.quantile_ns(0.5), 0.0);
    }

    #[test]
    fn mean_exact() {
        let h = LatencyHistogram::new();
        h.record_ns(1000);
        h.record_ns(3000);
        assert_eq!(h.mean_ns(), 2000.0);
    }

    #[test]
    fn quantiles_ordered_and_bracketing() {
        let h = LatencyHistogram::new();
        for i in 1..=1000u64 {
            h.record_ns(i * 1000); // 1us .. 1ms
        }
        let p50 = h.quantile_ns(0.5);
        let p99 = h.quantile_ns(0.99);
        assert!(p50 <= p99);
        // log-bucket resolution: within a GROWTH factor of truth
        assert!(p50 > 500_000.0 / GROWTH && p50 < 500_000.0 * GROWTH * GROWTH,
                "{p50}");
    }

    #[test]
    fn concurrent_recording() {
        let h = std::sync::Arc::new(LatencyHistogram::new());
        let mut handles = Vec::new();
        for t in 0..4 {
            let h = h.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..1000u64 {
                    h.record_ns((t * 1000 + i) * 10);
                }
            }));
        }
        for j in handles {
            j.join().unwrap();
        }
        assert_eq!(h.count(), 4000);
    }

    #[test]
    fn huge_values_clamped() {
        let h = LatencyHistogram::new();
        h.record_ns(u64::MAX / 2);
        assert_eq!(h.count(), 1);
        assert!(h.quantile_ns(1.0) > 0.0);
    }
}
