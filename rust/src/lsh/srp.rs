//! Sign random projections (SimHash) — angular-distance LSH.
//!
//! `h_t(x) = sign(a_t · x)` with the same Achlioptas-sparse ±1 projections
//! as [`super::l2`].  Collision probability `1 − θ(x, y)/π` (Goemans–
//! Williamson).  Not used by the Representer-Sketch defaults but part of
//! the LSH substrate (paper §2.2 lists it as a canonical LSH kernel).

use super::LshFamily;
use crate::util::rng::SplitMix64;

#[derive(Clone, Debug)]
pub struct SrpLsh {
    dim: usize,
    n_hashes: usize,
    pos_off: Vec<u32>,
    pos_idx: Vec<u32>,
    neg_off: Vec<u32>,
    neg_idx: Vec<u32>,
}

impl SrpLsh {
    pub fn generate(seed: u64, dim: usize, n_hashes: usize) -> Self {
        let mut rng = SplitMix64::new(seed);
        let (mut pos_off, mut neg_off) = (vec![0u32], vec![0u32]);
        let (mut pos_idx, mut neg_idx) = (Vec::new(), Vec::new());
        for _ in 0..n_hashes {
            for i in 0..dim {
                let u = rng.next_f64();
                let iu = u32::try_from(i)
                    .expect("SRP dimension index exceeds u32");
                if u < 1.0 / 6.0 {
                    pos_idx.push(iu);
                } else if u > 5.0 / 6.0 {
                    neg_idx.push(iu);
                }
            }
            // The CSR offsets are entry counts (≤ n_hashes · dim); a
            // silent `as u32` wrap here would scramble every slice
            // boundary, so both are checked conversions.
            pos_off.push(
                u32::try_from(pos_idx.len())
                    .expect("SRP +1 entry count exceeds u32"),
            );
            neg_off.push(
                u32::try_from(neg_idx.len())
                    .expect("SRP -1 entry count exceeds u32"),
            );
        }
        Self { dim, n_hashes, pos_off, pos_idx, neg_off, neg_idx }
    }

    /// Theoretical collision probability for angle theta (radians).
    pub fn collision_prob(theta: f64) -> f64 {
        1.0 - theta / std::f64::consts::PI
    }
}

impl LshFamily for SrpLsh {
    fn n_hashes(&self) -> usize {
        self.n_hashes
    }

    fn dim(&self) -> usize {
        self.dim
    }

    #[inline]
    fn hash_into(&self, x: &[f32], out: &mut [i32]) {
        for t in 0..self.n_hashes {
            let mut acc = 0.0f32;
            let plo = self.pos_off[t] as usize; // CAST: u32 offset widens
            let phi = self.pos_off[t + 1] as usize;
            for &i in &self.pos_idx[plo..phi] {
                acc += x[i as usize]; // CAST: u32 index widens
            }
            let nlo = self.neg_off[t] as usize; // CAST: u32 offset widens
            let nhi = self.neg_off[t + 1] as usize;
            for &i in &self.neg_idx[nlo..nhi] {
                acc -= x[i as usize]; // CAST: u32 index widens
            }
            out[t] = i32::from(acc >= 0.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    #[test]
    fn codes_are_binary() {
        let f = SrpLsh::generate(1, 8, 64);
        let mut rng = SplitMix64::new(2);
        let x: Vec<f32> =
            (0..8).map(|_| rng.next_gaussian() as f32).collect();
        assert!(f.hash(&x).iter().all(|&c| c == 0 || c == 1));
    }

    #[test]
    fn scale_invariant() {
        let f = SrpLsh::generate(3, 12, 128);
        let mut rng = SplitMix64::new(4);
        let x: Vec<f32> =
            (0..12).map(|_| rng.next_gaussian() as f32).collect();
        let x2: Vec<f32> = x.iter().map(|v| v * 7.5).collect();
        assert_eq!(f.hash(&x), f.hash(&x2));
    }

    #[test]
    fn antipodal_flips_most_codes() {
        let f = SrpLsh::generate(5, 10, 500);
        let mut rng = SplitMix64::new(6);
        let x: Vec<f32> =
            (0..10).map(|_| rng.next_gaussian() as f32).collect();
        let neg: Vec<f32> = x.iter().map(|v| -v).collect();
        let hx = f.hash(&x);
        let hn = f.hash(&neg);
        let agree = hx.iter().zip(&hn).filter(|(a, b)| a == b).count();
        // sign(-a·x) != sign(a·x) except when a·x == 0 (empty rows).
        assert!(agree < 60, "agree {agree}");
    }

    #[test]
    fn collision_rate_tracks_angle() {
        let f = SrpLsh::generate(7, 24, 4000);
        let mut rng = SplitMix64::new(8);
        let x: Vec<f32> =
            (0..24).map(|_| rng.next_gaussian() as f32).collect();
        // Construct y at a 45-degree angle from x in a random plane.
        let mut z: Vec<f32> =
            (0..24).map(|_| rng.next_gaussian() as f32).collect();
        let xn = x.iter().map(|v| v * v).sum::<f32>().sqrt();
        let dot = x.iter().zip(&z).map(|(a, b)| a * b).sum::<f32>();
        // Gram-Schmidt z against x.
        z.iter_mut()
            .zip(&x)
            .for_each(|(zi, xi)| *zi -= dot / (xn * xn) * xi);
        let zn = z.iter().map(|v| v * v).sum::<f32>().sqrt();
        let theta = std::f64::consts::FRAC_PI_4;
        let y: Vec<f32> = x
            .iter()
            .zip(&z)
            .map(|(xi, zi)| {
                xi / xn * (theta.cos() as f32) + zi / zn * (theta.sin() as f32)
            })
            .collect();
        let hx = f.hash(&x);
        let hy = f.hash(&y);
        let rate = hx.iter().zip(&hy).filter(|(a, b)| a == b).count() as f64
            / hx.len() as f64;
        let want = SrpLsh::collision_prob(theta);
        assert!((rate - want).abs() < 0.05, "rate {rate} want {want}");
    }
}
