//! Locality-sensitive hashing (paper §2.2).
//!
//! * [`l2`] — the L2 (p-stable) LSH family with Achlioptas-sparse ±1
//!   projections: `h(x) = floor((a·x + b) / r)`.  The sparse structure is
//!   the paper's "addition and subtraction only" hashing (§3.4), and the
//!   collision probability is the universal LSH kernel of §3.3.
//! * [`srp`] — sign random projections (angular LSH), included as the
//!   second classic family for the library's generality; not used by the
//!   sketch defaults.
//! * [`concat`] — K-wise concatenation rehashed to a column index in
//!   [0, R) (FNV-1a, row-salted) — identical to the python side.
//! * [`rng`] — re-export of the shared splitmix64.

pub mod concat;
pub mod l2;
pub mod srp;

pub use concat::rehash_row;
pub use l2::SparseL2Lsh;
pub use srp::SrpLsh;

/// A hash family mapping vectors to integer codes.
pub trait LshFamily {
    /// Number of independent hash functions.
    fn n_hashes(&self) -> usize;
    /// Input dimensionality.
    fn dim(&self) -> usize;
    /// Compute all codes for `x` into `out` (len == n_hashes()).
    fn hash_into(&self, x: &[f32], out: &mut [i32]);

    fn hash(&self, x: &[f32]) -> Vec<i32> {
        let mut out = vec![0; self.n_hashes()];
        self.hash_into(x, &mut out);
        out
    }
}
