//! K-wise hash concatenation → column index (paper §3.4: "each LSH
//! function h_l is constructed by concatenating K independent LSH
//! functions ... mapped to Z using a suitable transformation").
//!
//! The transformation is FNV-1a over the K codes, salted by the row index,
//! reduced mod R.  Wrapping u32 arithmetic — EXACTLY mirrored by
//! `ref.py::rehash_columns` (the parity fixture locks both sides).

pub const FNV_OFFSET: u32 = 0x811C_9DC5;
pub const FNV_PRIME: u32 = 0x0100_0193;
pub const ROW_SALT: u32 = 0x9E37_79B1;

/// Column index in [0, n_cols) for row `row` given that row's K codes.
#[inline]
pub fn rehash_row(row: u32, codes: &[i32], n_cols: u32) -> u32 {
    let mut acc = FNV_OFFSET ^ row.wrapping_mul(ROW_SALT);
    for &c in codes {
        acc = (acc ^ (c as u32)).wrapping_mul(FNV_PRIME);
    }
    acc % n_cols
}

/// Rehash a full code vector (L rows × K codes, hash-major) into per-row
/// column indices.  §Perf: the default column counts are powers of two,
/// where `% n_cols` (one div per row, 20-40 cycles) reduces to a mask —
/// results are identical, so python parity is preserved for every R.
pub fn rehash_all(codes: &[i32], k_per_row: usize, n_cols: u32, out: &mut [u32]) {
    rehash_all_rows(codes, k_per_row, n_cols, 0, out);
}

/// [`rehash_all`] for a contiguous row *slice* of a larger sketch: the
/// codes belong to global rows `row_offset..row_offset + out.len()`, so
/// the FNV row salt uses the GLOBAL row index.  This is what lets a
/// `shard::SketchShard` hash only its own repetitions yet land on
/// exactly the columns the monolithic sketch would — `rehash_all` is the
/// `row_offset = 0` case, byte-identical mixing either way.
pub fn rehash_all_rows(
    codes: &[i32],
    k_per_row: usize,
    n_cols: u32,
    row_offset: u32,
    out: &mut [u32],
) {
    debug_assert_eq!(codes.len() % k_per_row, 0);
    let n_rows = codes.len() / k_per_row;
    debug_assert_eq!(out.len(), n_rows);
    if n_cols.is_power_of_two() {
        let mask = n_cols - 1;
        for (l, slot) in out.iter_mut().enumerate() {
            let row = row_offset.wrapping_add(l as u32);
            let mut acc = FNV_OFFSET ^ row.wrapping_mul(ROW_SALT);
            for &c in &codes[l * k_per_row..(l + 1) * k_per_row] {
                acc = (acc ^ (c as u32)).wrapping_mul(FNV_PRIME);
            }
            *slot = acc & mask;
        }
    } else {
        for (l, slot) in out.iter_mut().enumerate() {
            *slot = rehash_row(
                row_offset.wrapping_add(l as u32),
                &codes[l * k_per_row..(l + 1) * k_per_row],
                n_cols,
            );
        }
    }
}

/// Batch-major variant of [`rehash_all`]: codes arrive in the transposed
/// layout of the batched hash kernel, `codes[(l*k_per_row + k)*batch + b]`,
/// and per-row columns leave as `out[l*batch + b]`.  The FNV mix is the
/// same wrapping u32 arithmetic as [`rehash_row`] (and the power-of-two
/// mask shortcut of [`rehash_all`]), so results are integer-exact matches
/// of the scalar path for every (row, query).
pub fn rehash_all_batch(
    codes: &[i32],
    k_per_row: usize,
    n_cols: u32,
    batch: usize,
    out: &mut [u32],
) {
    rehash_all_batch_rows(codes, k_per_row, n_cols, batch, 0, out);
}

/// [`rehash_all_batch`] over a contiguous row slice (see
/// [`rehash_all_rows`]): row `l` of the slice salts with the global
/// index `row_offset + l`.  Shared mixing with the scalar path, so a
/// shard's batched columns match the monolithic sketch integer-exactly.
pub fn rehash_all_batch_rows(
    codes: &[i32],
    k_per_row: usize,
    n_cols: u32,
    batch: usize,
    row_offset: u32,
    out: &mut [u32],
) {
    if batch == 0 {
        return;
    }
    debug_assert_eq!(codes.len() % (k_per_row * batch), 0);
    let n_rows = codes.len() / (k_per_row * batch);
    debug_assert_eq!(out.len(), n_rows * batch);
    let pow2_mask =
        if n_cols.is_power_of_two() { Some(n_cols - 1) } else { None };
    for l in 0..n_rows {
        let row = row_offset.wrapping_add(l as u32);
        let orow = &mut out[l * batch..(l + 1) * batch];
        orow.fill(FNV_OFFSET ^ row.wrapping_mul(ROW_SALT));
        for k in 0..k_per_row {
            let crow = &codes[(l * k_per_row + k) * batch..][..batch];
            for (o, &c) in orow.iter_mut().zip(crow) {
                *o = (*o ^ (c as u32)).wrapping_mul(FNV_PRIME);
            }
        }
        match pow2_mask {
            Some(mask) => orow.iter_mut().for_each(|o| *o &= mask),
            None => orow.iter_mut().for_each(|o| *o %= n_cols),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn in_range() {
        forall(
            1,
            500,
            |rng| {
                let k = 1 + rng.next_range(4);
                let codes: Vec<i32> = (0..k)
                    .map(|_| rng.next_u64() as i32)
                    .collect();
                let cols = 1 + rng.next_range(64) as u32;
                let row = rng.next_u64() as u32;
                (row, codes, cols)
            },
            |(row, codes, cols)| {
                let c = rehash_row(*row, codes, *cols);
                if c < *cols {
                    Ok(())
                } else {
                    Err(format!("col {c} >= {cols}"))
                }
            },
        );
    }

    #[test]
    fn row_salt_decorrelates_rows() {
        // Same codes in different rows must map to different columns
        // often (else rows would be perfectly correlated).
        let codes = [3i32, -7, 11];
        let mut distinct = std::collections::HashSet::new();
        for row in 0..64u32 {
            distinct.insert(rehash_row(row, &codes, 1024));
        }
        assert!(distinct.len() > 48, "only {} distinct", distinct.len());
    }

    #[test]
    fn sensitive_to_each_code() {
        let base = [5i32, 9, -2];
        let c0 = rehash_row(0, &base, 1 << 20);
        for i in 0..3 {
            let mut m = base;
            m[i] += 1;
            assert_ne!(rehash_row(0, &m, 1 << 20), c0, "code {i} ignored");
        }
    }

    #[test]
    fn rehash_all_matches_rehash_row() {
        let codes: Vec<i32> = (0..12).map(|i| i * 3 - 5).collect();
        let mut out = vec![0u32; 4];
        rehash_all(&codes, 3, 17, &mut out);
        for l in 0..4 {
            assert_eq!(
                out[l],
                rehash_row(l as u32, &codes[l * 3..(l + 1) * 3], 17)
            );
        }
    }

    #[test]
    fn rehash_all_batch_matches_rehash_row() {
        forall(
            17,
            60,
            |rng| {
                let k = 1 + rng.next_range(4);
                let rows = 1 + rng.next_range(8);
                let batch = 1 + rng.next_range(9);
                let cols = 1 + rng.next_range(64) as u32;
                let codes: Vec<i32> = (0..rows * k * batch)
                    .map(|_| rng.next_u64() as i32)
                    .collect();
                (k, rows, batch, cols, codes)
            },
            |(k, rows, batch, cols, codes)| {
                let (k, rows, batch, cols) = (*k, *rows, *batch, *cols);
                let mut out = vec![0u32; rows * batch];
                rehash_all_batch(codes, k, cols, batch, &mut out);
                for b in 0..batch {
                    for l in 0..rows {
                        // de-transpose query b's codes for row l
                        let qcodes: Vec<i32> = (0..k)
                            .map(|ki| codes[(l * k + ki) * batch + b])
                            .collect();
                        let want = rehash_row(l as u32, &qcodes, cols);
                        if out[l * batch + b] != want {
                            return Err(format!(
                                "row {l} query {b}: {} vs {want}",
                                out[l * batch + b]
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn row_slices_reproduce_the_monolithic_columns() {
        // The shard contract: rehashing a contiguous row slice with its
        // global offset yields exactly the columns the full-sketch call
        // computes for those rows — scalar and batch-major, pow2 and
        // non-pow2 column counts.
        forall(
            29,
            40,
            |rng| {
                let k = 1 + rng.next_range(3);
                let rows = 2 + rng.next_range(12);
                let batch = 1 + rng.next_range(6);
                let cols = [16u32, 15, 64][rng.next_range(3)];
                let codes: Vec<i32> = (0..rows * k * batch)
                    .map(|_| rng.next_u64() as i32)
                    .collect();
                let r0 = rng.next_range(rows);
                let r1 = r0 + 1 + rng.next_range(rows - r0);
                (k, rows, batch, cols, codes, r0, r1)
            },
            |(k, rows, batch, cols, codes, r0, r1)| {
                let (k, rows, batch, cols, r0, r1) =
                    (*k, *rows, *batch, *cols, *r0, *r1);
                // Scalar layout: de-transpose query 0's codes.
                let scalar: Vec<i32> = (0..rows * k)
                    .map(|h| codes[h * batch])
                    .collect();
                let mut full = vec![0u32; rows];
                rehash_all(&scalar, k, cols, &mut full);
                let mut part = vec![0u32; r1 - r0];
                rehash_all_rows(&scalar[r0 * k..r1 * k], k, cols,
                                r0 as u32, &mut part);
                if part != full[r0..r1] {
                    return Err("scalar slice diverged".into());
                }
                // Batch-major layout over the same slice.
                let mut full_b = vec![0u32; rows * batch];
                rehash_all_batch(codes, k, cols, batch, &mut full_b);
                let mut part_b = vec![0u32; (r1 - r0) * batch];
                rehash_all_batch_rows(
                    &codes[r0 * k * batch..r1 * k * batch],
                    k, cols, batch, r0 as u32, &mut part_b,
                );
                if part_b != full_b[r0 * batch..r1 * batch] {
                    return Err("batch slice diverged".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn distribution_roughly_uniform() {
        // Hash many random code tuples into 16 columns; chi-square-ish.
        let mut counts = [0usize; 16];
        let mut rng = crate::util::rng::SplitMix64::new(3);
        let n = 16_000;
        for _ in 0..n {
            let codes = [rng.next_u64() as i32, rng.next_u64() as i32];
            counts[rehash_row(0, &codes, 16) as usize] += 1;
        }
        let expect = n / 16;
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                (c as f64 - expect as f64).abs() < expect as f64 * 0.15,
                "bucket {i}: {c} vs {expect}"
            );
        }
    }
}
