//! L2 (p-stable) LSH with Achlioptas-sparse {−1, 0, +1} projections.
//!
//! `h_t(x) = floor((a_t · x + b_t) / r)` where `a_t` has P[±1] = 1/6 each
//! (Achlioptas, s = 3) and `b_t ~ U[0, r)`.  Parameter generation is a
//! pure function of the seed and matches `ref.py::gen_l2lsh_params`
//! bit-for-bit (hash-major stream from `seed`; biases from
//! `seed ^ BIAS_SEED_XOR`).
//!
//! Two evaluation paths:
//! * `hash_into` — the **hot path**: CSR-style sparse ±1 accumulation,
//!   i.e. only additions and subtractions (the paper's energy story).
//! * `dense_projection` — materialize the (d, H) matrix for parity tests
//!   and for feeding the L1 Pallas kernel's dense layout.

use super::LshFamily;
use crate::util::rng::SplitMix64;

/// Seed offset for the bias stream (mirrors ref.py BIAS_SEED_XOR).
pub const BIAS_SEED_XOR: u64 = 0xB1A5_B1A5_B1A5_B1A5;

/// One L2-LSH family: `n_hashes` functions over `dim` inputs.
#[derive(Clone, Debug)]
pub struct SparseL2Lsh {
    dim: usize,
    n_hashes: usize,
    /// Bucket width r.
    pub width: f32,
    /// Per-hash sparse rows: flat +1 indices / −1 indices with offsets
    /// (CSR).  `pos_idx[pos_off[t]..pos_off[t+1]]` are coordinates added.
    pos_off: Vec<u32>,
    pos_idx: Vec<u32>,
    neg_off: Vec<u32>,
    neg_idx: Vec<u32>,
    bias: Vec<f32>,
    inv_width: f32,
    /// Coordinate-major (CSC) view for the batched hot path: for input
    /// coordinate i, `csc_entries[csc_off[i]..csc_off[i+1]]` lists the
    /// hash functions touching it, sign packed in the top bit
    /// (§Perf: turns H small sparse dot products into p sequential
    /// scatter walks over an L1-resident accumulator).
    csc_off: Vec<u32>,
    csc_entries: Vec<u32>,
}

const SIGN_BIT: u32 = 1 << 31;

/// Explicit lane width of the batch consumer loop (mirrors
/// `sketch::quant::LANES` — both gathers use the same 8-wide chunk
/// structure).
const LANES: usize = 8;

/// Branchless floor-to-i32 (§Perf: `f32::floor` lowers to a libm PLT call
/// on this toolchain — 8% of the query profile).  Exact for |v| < 2^31,
/// which L2-LSH code magnitudes satisfy by construction (values are
/// (a·x + b)/r over standardized data).
#[inline(always)]
fn fast_floor(v: f32) -> i32 {
    // CAST: |v| < 2^31 by construction (doc above) — truncation exact.
    let t = v as i32;
    // CAST: i32 -> f32 compare + bool -> {0, 1} correction term.
    t - ((v < t as f32) as i32)
}

impl SparseL2Lsh {
    /// Deterministically generate the family from a seed.
    pub fn generate(seed: u64, dim: usize, n_hashes: usize, width: f32) -> Self {
        let mut rng = SplitMix64::new(seed);
        let mut pos_off = Vec::with_capacity(n_hashes + 1);
        let mut neg_off = Vec::with_capacity(n_hashes + 1);
        let mut pos_idx = Vec::new();
        let mut neg_idx = Vec::new();
        pos_off.push(0);
        neg_off.push(0);
        for _t in 0..n_hashes {
            for i in 0..dim {
                let u = rng.next_f64();
                let iu = u32::try_from(i)
                    .expect("L2-LSH dimension index exceeds u32");
                if u < 1.0 / 6.0 {
                    pos_idx.push(iu);
                } else if u > 5.0 / 6.0 {
                    neg_idx.push(iu);
                }
            }
            // Checked: a wrapped CSR offset would scramble every slice
            // boundary downstream.
            pos_off.push(
                u32::try_from(pos_idx.len())
                    .expect("L2-LSH +1 entry count exceeds u32"),
            );
            neg_off.push(
                u32::try_from(neg_idx.len())
                    .expect("L2-LSH -1 entry count exceeds u32"),
            );
        }
        let mut brng = SplitMix64::new(seed ^ BIAS_SEED_XOR);
        let bias: Vec<f32> = (0..n_hashes)
            // CAST: f32 width -> f64 widens; the U[0, width) product
            // rounds back to f32 (the reference stream's exact op order).
            .map(|_| (brng.next_f64() * width as f64) as f32)
            .collect();

        Self::from_csr(dim, n_hashes, width, pos_off, pos_idx, neg_off,
                       neg_idx, bias)
    }

    /// Assemble a family from its CSR rows + biases, building the
    /// coordinate-major (CSC) view (counting sort by coordinate).  The
    /// single assembly path shared by [`Self::generate`] and
    /// [`Self::slice`], so the per-hash accumulation order — coordinate
    /// ascending, the order every bit-identity proof rests on — is the
    /// same no matter how the CSR was obtained.
    #[allow(clippy::too_many_arguments)]
    fn from_csr(
        dim: usize,
        n_hashes: usize,
        width: f32,
        pos_off: Vec<u32>,
        pos_idx: Vec<u32>,
        neg_off: Vec<u32>,
        neg_idx: Vec<u32>,
        bias: Vec<f32>,
    ) -> Self {
        let span = |off: &[u32], t: usize| {
            // CAST: u32 CSR offsets -> usize slice bounds widen.
            (off[t] as usize, off[t + 1] as usize)
        };
        let mut counts = vec![0u32; dim + 1];
        for t in 0..n_hashes {
            let (plo, phi) = span(&pos_off, t);
            for &i in &pos_idx[plo..phi] {
                counts[i as usize + 1] += 1; // CAST: u32 index widens
            }
            let (nlo, nhi) = span(&neg_off, t);
            for &i in &neg_idx[nlo..nhi] {
                counts[i as usize + 1] += 1; // CAST: u32 index widens
            }
        }
        for i in 0..dim {
            counts[i + 1] += counts[i];
        }
        let csc_off = counts.clone();
        let mut fill = counts;
        // CAST: total entry count, u32 -> usize widens.
        let n_entries = *csc_off.last().unwrap() as usize;
        let mut csc_entries = vec![0u32; n_entries];
        // Pack hash index t into a u32 entry (top bit = sign).  Checked
        // once here rather than per entry: every `t as u32` below is
        // in-range and clear of SIGN_BIT.
        let _ = u32::try_from(n_hashes)
            .ok()
            .filter(|&n| n & SIGN_BIT == 0)
            .expect("L2-LSH hash count exceeds the 31-bit entry space");
        for t in 0..n_hashes {
            let tu = t as u32; // CAST: in-range by the check above
            let (plo, phi) = span(&pos_off, t);
            for &i in &pos_idx[plo..phi] {
                let slot = fill[i as usize]; // CAST: u32 index widens
                csc_entries[slot as usize] = tu; // CAST: u32 slot widens
                fill[i as usize] += 1; // CAST: u32 index widens
            }
            let (nlo, nhi) = span(&neg_off, t);
            for &i in &neg_idx[nlo..nhi] {
                let slot = fill[i as usize]; // CAST: u32 index widens
                csc_entries[slot as usize] = tu | SIGN_BIT; // CAST: widens
                fill[i as usize] += 1; // CAST: u32 index widens
            }
        }

        Self {
            dim,
            n_hashes,
            width,
            pos_off,
            pos_idx,
            neg_off,
            neg_idx,
            bias,
            inv_width: 1.0 / width,
            csc_off,
            csc_entries,
        }
    }

    /// Extract the sub-family of hashes `[hash_start, hash_end)` as a
    /// standalone family with LOCAL hash indices `0..hash_end −
    /// hash_start`.  Hash `t` of the slice computes bit-for-bit the same
    /// code as hash `hash_start + t` of `self`: the projections, biases,
    /// and the coordinate-ascending accumulation order are all preserved
    /// (property-tested below).  This is how a `shard::SketchShard`
    /// hashes only its own repetitions — the sharded hash work totals
    /// exactly one monolithic pass, just distributed.
    pub fn slice(&self, hash_start: usize, hash_end: usize) -> Self {
        assert!(hash_start <= hash_end && hash_end <= self.n_hashes,
                "slice [{hash_start}, {hash_end}) out of {}", self.n_hashes);
        let n = hash_end - hash_start;
        let pbase = self.pos_off[hash_start];
        let nbase = self.neg_off[hash_start];
        let pos_off: Vec<u32> = self.pos_off
            [hash_start..=hash_end]
            .iter()
            .map(|&o| o - pbase)
            .collect();
        let neg_off: Vec<u32> = self.neg_off
            [hash_start..=hash_end]
            .iter()
            .map(|&o| o - nbase)
            .collect();
        // CAST: CSR offsets are u32 -> usize widens (slice bounds).
        let (pb, pe) = (pbase as usize, self.pos_off[hash_end] as usize);
        let pos_idx = self.pos_idx[pb..pe].to_vec();
        // CAST: CSR offsets are u32 -> usize widens (slice bounds).
        let (nb, ne) = (nbase as usize, self.neg_off[hash_end] as usize);
        let neg_idx = self.neg_idx[nb..ne].to_vec();
        let bias = self.bias[hash_start..hash_end].to_vec();
        Self::from_csr(self.dim, n, self.width, pos_off, pos_idx, neg_off,
                       neg_idx, bias)
    }

    /// Batched hot-path hashing: coordinate-major accumulation into a
    /// caller-provided f32 buffer, then a single floor pass.  Identical
    /// results to `hash_into` (tested), substantially faster when
    /// n_hashes ≫ dim (the sketch regime: H = L·K, dim = p ≤ 16).
    #[inline]
    pub fn hash_into_acc(&self, x: &[f32], acc: &mut [f32],
                         out: &mut [i32]) {
        debug_assert_eq!(x.len(), self.dim);
        debug_assert_eq!(acc.len(), self.n_hashes);
        debug_assert_eq!(out.len(), self.n_hashes);
        acc.copy_from_slice(&self.bias);
        for (i, &xi) in x.iter().enumerate() {
            if xi == 0.0 {
                continue;
            }
            let lo = self.csc_off[i] as usize; // CAST: u32 offset widens
            let hi = self.csc_off[i + 1] as usize;
            let xi_bits = xi.to_bits();
            for &e in &self.csc_entries[lo..hi] {
                // CAST: hash index, u32 -> usize widens.
                let t = (e & !SIGN_BIT) as usize;
                // Branchless sign application: the packed sign bit is
                // exactly the f32 sign-bit position (§Perf: the ± branch
                // mispredicts ~50% otherwise).
                let signed = f32::from_bits(xi_bits ^ (e & SIGN_BIT));
                // SAFETY: t < n_hashes by construction.
                unsafe { *acc.get_unchecked_mut(t) += signed };
            }
        }
        for (o, &a) in out.iter_mut().zip(acc.iter()) {
            *o = fast_floor(a * self.inv_width);
        }
    }

    /// Batch-major hot-path hashing: one traversal of the CSC structure
    /// serves all `batch` queries (§Perf: the entry load + sign decode is
    /// amortized B ways, and the inner loop over the batch dimension is a
    /// contiguous auto-vectorizable add).
    ///
    /// Layouts are transposed so the batch dimension is innermost:
    /// * `xt` — inputs, coordinate-major `(dim, batch)`:
    ///   `xt[i * batch + b]` is coordinate `i` of query `b`.
    /// * `acc`/`out` — hash-major `(n_hashes, batch)`:
    ///   `acc[t * batch + b]`.
    ///
    /// Bit-for-bit identical per query to [`Self::hash_into_acc`]: same
    /// bias layout, same coordinate-ascending accumulation order, same
    /// `fast_floor`.  (Skipped zero coordinates in the scalar path are
    /// `±0.0` adds here; the accumulator can never be `-0.0` — it starts
    /// at a non-negative bias and IEEE-754 exact cancellation yields
    /// `+0.0` — so those adds are exact no-ops.)
    pub fn hash_batch_into_acc(
        &self,
        xt: &[f32],
        batch: usize,
        acc: &mut [f32],
        out: &mut [i32],
    ) {
        debug_assert_eq!(xt.len(), self.dim * batch);
        debug_assert_eq!(acc.len(), self.n_hashes * batch);
        debug_assert_eq!(out.len(), self.n_hashes * batch);
        if batch == 0 {
            return;
        }
        for (t, &bias) in self.bias.iter().enumerate() {
            acc[t * batch..(t + 1) * batch].fill(bias);
        }
        for i in 0..self.dim {
            let col = &xt[i * batch..(i + 1) * batch];
            if col.iter().all(|&v| v == 0.0) {
                continue; // exact no-op for every lane (see doc above)
            }
            let lo = self.csc_off[i] as usize; // CAST: u32 offset widens
            let hi = self.csc_off[i + 1] as usize;
            for &e in &self.csc_entries[lo..hi] {
                // CAST: hash index, u32 -> usize widens.
                let t = (e & !SIGN_BIT) as usize;
                let sign = e & SIGN_BIT;
                // SAFETY: t < n_hashes by construction, so the row
                // [t*batch, (t+1)*batch) lies inside `acc`.
                let row = unsafe {
                    acc.get_unchecked_mut(t * batch..(t + 1) * batch)
                };
                // Lane-explicit accumulate (§Perf): same element-wise add
                // in the same order as a plain zip, so bit-identical by
                // construction (locked by the batch-vs-scalar and
                // slice-vs-full property tests below); the fixed-width
                // chunks give the backend straight-line 8-lane bodies.
                let mut oi = row.chunks_exact_mut(LANES);
                let mut xi = col.chunks_exact(LANES);
                for (os, xs) in (&mut oi).zip(&mut xi) {
                    for j in 0..LANES {
                        os[j] += f32::from_bits(xs[j].to_bits() ^ sign);
                    }
                }
                for (o, &x) in
                    oi.into_remainder().iter_mut().zip(xi.remainder())
                {
                    *o += f32::from_bits(x.to_bits() ^ sign);
                }
            }
        }
        for (o, &a) in out.iter_mut().zip(acc.iter()) {
            *o = fast_floor(a * self.inv_width);
        }
    }

    /// Materialize the dense (dim, n_hashes) ±1 projection (column-major
    /// by hash): `out[i * n_hashes + t]`.
    pub fn dense_projection(&self) -> Vec<f32> {
        let mut m = vec![0.0f32; self.dim * self.n_hashes];
        for t in 0..self.n_hashes {
            let plo = self.pos_off[t] as usize; // CAST: u32 offset widens
            let phi = self.pos_off[t + 1] as usize;
            for &i in &self.pos_idx[plo..phi] {
                m[i as usize * self.n_hashes + t] = 1.0; // CAST: widens
            }
            let nlo = self.neg_off[t] as usize; // CAST: u32 offset widens
            let nhi = self.neg_off[t + 1] as usize;
            for &i in &self.neg_idx[nlo..nhi] {
                m[i as usize * self.n_hashes + t] = -1.0; // CAST: widens
            }
        }
        m
    }

    pub fn bias(&self) -> &[f32] {
        &self.bias
    }

    /// Number of nonzero projection entries (for FLOPs accounting:
    /// expected ≈ dim * n_hashes / 3).
    pub fn nnz(&self) -> usize {
        self.pos_idx.len() + self.neg_idx.len()
    }
}

impl LshFamily for SparseL2Lsh {
    fn n_hashes(&self) -> usize {
        self.n_hashes
    }

    fn dim(&self) -> usize {
        self.dim
    }

    #[inline]
    fn hash_into(&self, x: &[f32], out: &mut [i32]) {
        debug_assert_eq!(x.len(), self.dim);
        debug_assert_eq!(out.len(), self.n_hashes);
        for t in 0..self.n_hashes {
            let mut acc = self.bias[t];
            // Add/subtract only — the paper's §3.4 hot loop.
            let plo = self.pos_off[t] as usize; // CAST: u32 offset widens
            let phi = self.pos_off[t + 1] as usize;
            for &i in &self.pos_idx[plo..phi] {
                acc += x[i as usize]; // CAST: u32 index widens
            }
            let nlo = self.neg_off[t] as usize; // CAST: u32 offset widens
            let nhi = self.neg_off[t + 1] as usize;
            for &i in &self.neg_idx[nlo..nhi] {
                acc -= x[i as usize]; // CAST: u32 index widens
            }
            out[t] = fast_floor(acc * self.inv_width);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, gens};

    #[test]
    fn deterministic_generation() {
        let a = SparseL2Lsh::generate(9, 10, 20, 2.0);
        let b = SparseL2Lsh::generate(9, 10, 20, 2.0);
        assert_eq!(a.bias, b.bias);
        assert_eq!(a.pos_idx, b.pos_idx);
        assert_eq!(a.neg_idx, b.neg_idx);
    }

    #[test]
    fn sparsity_about_one_third() {
        let f = SparseL2Lsh::generate(3, 50, 400, 2.0);
        let frac = f.nnz() as f64 / (50.0 * 400.0);
        assert!((frac - 1.0 / 3.0).abs() < 0.02, "nnz frac {frac}");
    }

    #[test]
    fn bias_in_range() {
        let f = SparseL2Lsh::generate(4, 5, 100, 3.5);
        assert!(f.bias.iter().all(|&b| (0.0..3.5).contains(&b)));
    }

    #[test]
    fn sparse_matches_dense_projection() {
        let f = SparseL2Lsh::generate(17, 13, 31, 2.5);
        let m = f.dense_projection();
        forall(
            5,
            50,
            |rng| gens::vec_f32(rng, 13, 1.0),
            |x| {
                let sparse = f.hash(x);
                // dense recompute
                for t in 0..31 {
                    let mut acc = f.bias[t];
                    for i in 0..13 {
                        acc += m[i * 31 + t] * x[i];
                    }
                    let code = (acc / 2.5).floor() as i32;
                    if code != sparse[t] {
                        return Err(format!(
                            "hash {t}: dense {code} vs sparse {}",
                            sparse[t]
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn csc_path_matches_row_path() {
        // hash_into_acc must agree with hash_into bit-for-bit.
        forall(
            99,
            60,
            |rng| {
                let dim = 1 + rng.next_range(24);
                let h = 1 + rng.next_range(300);
                let f = SparseL2Lsh::generate(rng.next_u64(), dim, h, 2.0);
                let x = gens::vec_f32(rng, dim, 1.5);
                (f, x)
            },
            |(f, x)| {
                let want = f.hash(x);
                let mut acc = vec![0.0f32; f.n_hashes()];
                let mut got = vec![0i32; f.n_hashes()];
                f.hash_into_acc(x, &mut acc, &mut got);
                if want == got {
                    Ok(())
                } else {
                    Err("csc path diverged".into())
                }
            },
        );
    }

    #[test]
    fn batch_path_matches_scalar_path_bitwise() {
        // hash_batch_into_acc must agree with hash_into_acc per query,
        // bit for bit, for random (dim, H, B) — including B = 1, exact
        // zeros in the input, and batches that are not lane-multiples.
        forall(
            123,
            40,
            |rng| {
                let dim = 1 + rng.next_range(24);
                let h = 1 + rng.next_range(200);
                let b = 1 + rng.next_range(37);
                let f = SparseL2Lsh::generate(rng.next_u64(), dim, h, 2.0);
                let mut xs = Vec::with_capacity(b * dim);
                for _ in 0..b {
                    let mut x = gens::vec_f32(rng, dim, 1.5);
                    // plant exact zeros to exercise the skip paths
                    for v in x.iter_mut() {
                        if rng.next_f32() < 0.2 {
                            *v = 0.0;
                        }
                    }
                    xs.extend_from_slice(&x);
                }
                (f, xs, b, dim)
            },
            |(f, xs, b, dim)| {
                let (b, dim) = (*b, *dim);
                let h = f.n_hashes();
                // transpose inputs to (dim, b)
                let mut xt = vec![0.0f32; dim * b];
                for q in 0..b {
                    for i in 0..dim {
                        xt[i * b + q] = xs[q * dim + i];
                    }
                }
                let mut acc = vec![0.0f32; h * b];
                let mut got = vec![0i32; h * b];
                f.hash_batch_into_acc(&xt, b, &mut acc, &mut got);
                let mut sacc = vec![0.0f32; h];
                let mut want = vec![0i32; h];
                for q in 0..b {
                    f.hash_into_acc(&xs[q * dim..(q + 1) * dim], &mut sacc,
                                    &mut want);
                    for t in 0..h {
                        if got[t * b + q] != want[t] {
                            return Err(format!(
                                "query {q} hash {t}: batch {} vs scalar {}",
                                got[t * b + q], want[t]
                            ));
                        }
                        if acc[t * b + q].to_bits() != sacc[t].to_bits() {
                            return Err(format!(
                                "query {q} hash {t}: acc bits diverge"
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn sliced_family_matches_full_family_bitwise() {
        // The shard contract: hash t of slice(a, b) == hash a+t of the
        // full family, bit for bit, through both evaluation paths.
        forall(
            131,
            40,
            |rng| {
                let dim = 1 + rng.next_range(20);
                let h = 2 + rng.next_range(160);
                let f = SparseL2Lsh::generate(rng.next_u64(), dim, h, 2.0);
                let a = rng.next_range(h);
                let b = a + 1 + rng.next_range(h - a);
                let mut x = gens::vec_f32(rng, dim, 1.5);
                for v in x.iter_mut() {
                    if rng.next_f32() < 0.2 {
                        *v = 0.0;
                    }
                }
                (f, a, b, x)
            },
            |(f, a, b, x)| {
                let (a, b) = (*a, *b);
                let sub = f.slice(a, b);
                let h = f.n_hashes();
                let mut acc = vec![0.0f32; h];
                let mut full = vec![0i32; h];
                f.hash_into_acc(x, &mut acc, &mut full);
                let mut sacc = vec![0.0f32; b - a];
                let mut got = vec![0i32; b - a];
                sub.hash_into_acc(x, &mut sacc, &mut got);
                for (t, (&g, &w)) in
                    got.iter().zip(&full[a..b]).enumerate()
                {
                    if g != w {
                        return Err(format!("hash {t}: {g} vs {w}"));
                    }
                    if sacc[t].to_bits() != acc[a + t].to_bits() {
                        return Err(format!("hash {t}: acc bits diverge"));
                    }
                }
                // Batch path of the slice against the scalar slice.
                let batch = 3usize;
                let dim = f.dim();
                let mut xt = vec![0.0f32; dim * batch];
                for q in 0..batch {
                    for i in 0..dim {
                        xt[i * batch + q] = x[i];
                    }
                }
                let mut bacc = vec![0.0f32; (b - a) * batch];
                let mut bout = vec![0i32; (b - a) * batch];
                sub.hash_batch_into_acc(&xt, batch, &mut bacc, &mut bout);
                for t in 0..(b - a) {
                    for q in 0..batch {
                        if bout[t * batch + q] != got[t] {
                            return Err(format!(
                                "batch hash {t} query {q} diverged"
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn nearby_points_collide_more() {
        // Structural LSH property (Definition 2.1): closer pairs collide
        // with higher empirical probability.
        let dim = 16;
        let f = SparseL2Lsh::generate(7, dim, 2000, 3.0);
        let mut rng = SplitMix64::new(1);
        let x = gens::vec_f32(&mut rng, dim, 1.0);
        let mk_at = |dist: f32, rng: &mut SplitMix64| {
            let mut d = gens::vec_f32(rng, dim, 1.0);
            let n = (d.iter().map(|v| v * v).sum::<f32>()).sqrt();
            d.iter_mut().for_each(|v| *v *= dist / n);
            x.iter().zip(&d).map(|(a, b)| a + b).collect::<Vec<_>>()
        };
        let hx = f.hash(&x);
        let rate = |y: &[f32]| {
            let hy = f.hash(y);
            hx.iter().zip(&hy).filter(|(a, b)| a == b).count() as f64
                / hx.len() as f64
        };
        let near = rate(&mk_at(0.5, &mut rng));
        let mid = rate(&mk_at(2.0, &mut rng));
        let far = rate(&mk_at(6.0, &mut rng));
        assert!(near > mid && mid > far, "{near} {mid} {far}");
    }

    #[test]
    fn translation_by_width_shifts_code() {
        // Shifting x so a·x increases by exactly width increments the code.
        let f = SparseL2Lsh::generate(2, 6, 40, 2.0);
        let x = vec![0.3f32; 6];
        let codes = f.hash(&x);
        // Build a shift along hash 0's projection direction.
        let m = f.dense_projection();
        let a0: Vec<f32> = (0..6).map(|i| m[i * 40]).collect();
        let norm2: f32 = a0.iter().map(|v| v * v).sum();
        if norm2 == 0.0 {
            return; // empty projection row; nothing to assert
        }
        let y: Vec<f32> = x
            .iter()
            .zip(&a0)
            .map(|(xi, ai)| xi + ai * 2.0 / norm2)
            .collect();
        let cy = f.hash(&y);
        assert_eq!(cy[0], codes[0] + 1);
    }
}
