//! Deployment pipeline: from a distilled kernel model to the smallest
//! sketch that preserves accuracy, saved as a self-contained edge
//! artifact (RSSK) and verified after reload.
//!
//! This is the workflow a practitioner follows after `make artifacts`:
//! sweep sketch sizes (seconds — no retraining), pick the knee of the
//! accuracy/memory curve subject to a tolerance vs the exact kernel
//! model, ship the binary sketch.
//!
//! Run: `cargo run --release --example distill_deploy [dataset] [tol]`

use repsketch::data::{Dataset, Task};
use repsketch::kernel::{KernelModel, KernelParams};
use repsketch::metrics::cost;
use repsketch::nn::{Mlp, MlpScratch};
use repsketch::runtime::registry::DatasetMeta;
use repsketch::sketch::{QueryScratch, RaceSketch, SketchConfig};

fn main() -> anyhow::Result<()> {
    let name = std::env::args().nth(1).unwrap_or_else(|| "adult".into());
    let tol: f32 = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.01);
    let root = repsketch::artifacts_dir();
    anyhow::ensure!(root.join(".stamp").exists(),
                    "run `make artifacts` first");
    let dir = root.join(&name);
    let meta = DatasetMeta::load(&dir)?;
    let ds = Dataset::load_artifact(&root, &name, "test", meta.dim,
                                    meta.task)?;
    let kp = KernelParams::load(dir.join("kernel_params.bin"))?;
    let kernel = KernelModel::new(kp.clone());
    let teacher = Mlp::load(dir.join("nn_weights.bin"))?;

    // Reference scores.
    let mut ms = MlpScratch::default();
    let nn_preds: Vec<f32> =
        ds.rows().map(|r| teacher.forward_with(r, &mut ms)).collect();
    let kern_preds: Vec<f32> =
        ds.rows().map(|r| kernel.predict(r)).collect();
    let nn_score = ds.score(&nn_preds);
    let kern_score = ds.score(&kern_preds);
    println!(
        "{name}: teacher={nn_score:.4}  kernel={kern_score:.4}  \
         (tolerance {tol})"
    );

    // Sweep (rows, cols) ladders; keep the cheapest config within
    // tolerance of the kernel model's score.
    println!(
        "\n{:>6} {:>6} {:>10} {:>10} {:>10} {:>8}",
        "L", "R", "params", "vs NN", "score", "ok?"
    );
    let mut best: Option<(usize, usize, usize, f32)> = None;
    for rows in [100usize, 200, 300, 500, 1000, 2000] {
        for cols in [8usize, 16, 32] {
            let sk = RaceSketch::build(
                &kp,
                &SketchConfig { rows, cols, ..Default::default() },
            );
            let mut qs = QueryScratch::default();
            let preds: Vec<f32> =
                ds.rows().map(|r| sk.query_with(r, &mut qs)).collect();
            let score = ds.score(&preds);
            let ok = match meta.task {
                Task::Classification => score >= kern_score - tol,
                Task::Regression => score <= kern_score + tol,
            };
            let params = sk.param_count();
            println!(
                "{rows:>6} {cols:>6} {params:>10} {:>9.1}x {score:>10.4} \
                 {:>8}",
                teacher.param_count() as f64 / params as f64,
                if ok { "yes" } else { "-" }
            );
            if ok && best.map(|(_, _, bp, _)| params < bp).unwrap_or(true) {
                best = Some((rows, cols, params, score));
            }
        }
    }

    let (rows, cols, params, score) =
        best.ok_or_else(|| anyhow::anyhow!("no config within tolerance"))?;
    println!(
        "\nselected L={rows} R={cols}: {params} params \
         ({} MB at the paper's 64-bit convention), score {score:.4}, \
         {:.1}x smaller than the teacher",
        cost::fmt_mb(params),
        teacher.param_count() as f64 / params as f64
    );

    // Ship + verify.
    let sk = RaceSketch::build(
        &kp,
        &SketchConfig { rows, cols, ..Default::default() },
    );
    let out = std::env::temp_dir().join(format!("{name}_edge_sketch.bin"));
    sk.save(&out)?;
    let reloaded = RaceSketch::load(&out)?;
    let mut qs = QueryScratch::default();
    let preds: Vec<f32> =
        ds.rows().map(|r| reloaded.query_with(r, &mut qs)).collect();
    let reloaded_score = ds.score(&preds);
    assert_eq!(score, reloaded_score, "reload changed predictions");
    println!(
        "deploy artifact {} ({} bytes) verified after reload — \
         distill_deploy OK",
        out.display(),
        reloaded.serialized_size()
    );
    Ok(())
}
