//! Quickstart: the Representer-Sketch workflow on a self-contained toy
//! problem — no artifacts required.
//!
//! 1. Author a weighted kernel model (normally distilled from a neural
//!    network by `make artifacts`; here hand-built).
//! 2. Fold it into a RACE sketch (Algorithm 1).
//! 3. Query with add/sub hashing + counter reads (Algorithm 2) and
//!    compare against the exact weighted KDE.
//!
//! Run: `cargo run --release --example quickstart`

use repsketch::kernel::{KernelModel, KernelParams};
use repsketch::sketch::{QueryScratch, RaceSketch, SketchConfig};
use repsketch::util::rng::SplitMix64;

fn main() {
    // --- 1. a weighted kernel model over R^8 ------------------------------
    let (d, p, m) = (8usize, 8usize, 64usize);
    let mut rng = SplitMix64::new(42);
    let mut a = vec![0.0f32; d * p]; // identity projection (d == p)
    for i in 0..d {
        a[i * p + i] = 1.0;
    }
    let kp = KernelParams {
        d,
        p,
        m,
        a,
        x: (0..m * p).map(|_| rng.next_gaussian() as f32).collect(),
        alpha: (0..m).map(|_| 0.5 + rng.next_f32()).collect(),
        width: 2.5,
        lsh_seed: 0xC0FFEE,
        k_per_row: 2,
        default_rows: 400,
        default_cols: 16,
    };
    let exact = KernelModel::new(kp.clone());

    // --- 2. sketch it ------------------------------------------------------
    let sketch = RaceSketch::build(&kp, &SketchConfig::default());
    println!(
        "sketch: {} rows x {} cols = {} counters ({} bytes serialized)",
        sketch.rows,
        sketch.cols,
        sketch.counter_count(),
        sketch.serialized_size()
    );
    println!(
        "kernel model: {} params | sketch: {} params | FLOPs/query: {}",
        kp.param_count(),
        sketch.param_count(),
        sketch.flops_per_query()
    );

    // --- 3. query ----------------------------------------------------------
    let mut scratch = QueryScratch::default();
    println!("\n{:>4} {:>12} {:>12} {:>9}", "q#", "exact f_K", "sketch",
             "rel err");
    let mut worst = 0.0f32;
    for i in 0..8 {
        let q: Vec<f32> =
            (0..d).map(|_| rng.next_gaussian() as f32).collect();
        let want = exact.predict(&q);
        let got = sketch.query_with(&q, &mut scratch);
        let rel = (got - want).abs() / want.abs().max(1e-6);
        worst = worst.max(rel);
        println!("{i:>4} {want:>12.4} {got:>12.4} {:>8.2}%", rel * 100.0);
    }
    assert!(worst < 0.25, "sketch estimate diverged: {worst}");
    println!("\nquickstart OK (worst rel err {:.2}%)", worst * 100.0);
}
