//! Energy accounting (paper §4.3 "Energy Requirement", quantified with
//! the Horowitz ISSCC'14 45 nm numbers the paper cites): estimated energy
//! per inference for the NN vs the Representer Sketch on every dataset,
//! split into compute (mul/add) and memory (cache vs DRAM) components.
//!
//! Run: `cargo run --release --example energy_model`

use repsketch::metrics::energy::EnergyModel;
use repsketch::nn::Mlp;
use repsketch::runtime::registry::DatasetMeta;

fn main() -> anyhow::Result<()> {
    let root = repsketch::artifacts_dir();
    anyhow::ensure!(root.join(".stamp").exists(),
                    "run `make artifacts` first");
    let model = EnergyModel::default();
    println!(
        "energy model (45nm, Horowitz ISSCC'14): fp mul {} pJ, fp add {} \
         pJ, cache {} pJ, DRAM {} pJ, cache budget {} KiB\n",
        model.fp_mul_pj,
        model.fp_add_pj,
        model.cache_access_pj,
        model.dram_access_pj,
        model.cache_bytes / 1024
    );
    println!(
        "{:<10} {:>12} {:>10} {:>14} {:>12} {:>10}",
        "dataset", "NN (nJ)", "resident?", "sketch (nJ)", "resident?",
        "ratio"
    );
    println!("{}", "-".repeat(74));
    for name in repsketch::experiments::DATASETS {
        let dir = root.join(name);
        let meta = DatasetMeta::load(&dir)?;
        let mlp = Mlp::load(dir.join("nn_weights.bin"))?;
        let params = mlp.param_count();
        let flops = mlp.flops_per_query();
        // fvcore convention: flops = 2*out*in → half muls, half adds.
        let nn = model.nn_inference(params, flops / 2, flops / 2);
        let rs = model.sketch_inference(
            meta.dim,
            meta.kernel_p,
            meta.k_per_row,
            meta.default_rows,
            meta.default_cols,
        );
        let nn_resident =
            model.cache_resident(params * 8);
        let rs_params = meta.default_rows * meta.default_cols
            + meta.dim * meta.kernel_p;
        let rs_resident = model.cache_resident(rs_params * 8);
        println!(
            "{:<10} {:>12.2} {:>10} {:>14.3} {:>12} {:>9.0}x",
            name,
            nn.total_nj(),
            if nn_resident { "cache" } else { "DRAM" },
            rs.total_nj(),
            if rs_resident { "cache" } else { "DRAM" },
            nn.total_nj() / rs.total_nj()
        );
    }
    println!(
        "\n(The sketch always fits in cache; the larger NNs spill to DRAM \
         — the 65x-per-access gap of the paper's §1 dominates the ratio.)"
    );
    Ok(())
}
