//! **End-to-end driver** (EXPERIMENTS.md §E2E): serve every dataset over
//! TCP with all backends registered — the Representer-Sketch hot path,
//! the rust NN/Kernel engines, and the PJRT executables compiled from the
//! JAX/Pallas AOT artifacts — then drive a real batched client workload
//! through the socket and report accuracy, latency and throughput per
//! backend.
//!
//! This proves all layers compose: L1 Pallas kernel → L2 JAX model → HLO
//! text → rust PJRT runtime → dynamic batcher → router → TCP, with
//! Python nowhere on the request path.
//!
//! Run: `cargo run --release --example serve_edge [n_requests_per_lane]`

use repsketch::coordinator::batcher::BatcherConfig;
use repsketch::coordinator::{
    backend, BackendKind, Request, Response, Router, RouterConfig, Server,
};
use repsketch::data::Dataset;
use repsketch::runtime::registry::DatasetBundle;
use repsketch::runtime::Runtime;
use std::io::{BufRead, BufReader, Write};
use std::sync::Arc;
use std::time::{Duration, Instant};

const BACKENDS: [BackendKind; 4] = [
    BackendKind::Sketch,
    BackendKind::NnRust,
    BackendKind::KernelRust,
    BackendKind::NnPjrt,
];

fn main() -> anyhow::Result<()> {
    let n_per_lane: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(2000);
    let root = repsketch::artifacts_dir();
    anyhow::ensure!(root.join(".stamp").exists(),
                    "run `make artifacts` first");

    // --- build the router with every lane ---------------------------------
    let mut router = Router::new();
    let cfg = RouterConfig {
        batcher: BatcherConfig {
            max_batch: 32,
            max_wait: Duration::from_millis(1),
            queue_cap: 65_536,
        },
    };
    let datasets = ["adult", "phishing", "skin", "susy", "abalone",
                    "yearmsd"];
    let mut testsets = Vec::new();
    for name in datasets {
        let bundle = DatasetBundle::load(&root, name)?;
        let meta = bundle.meta.clone();
        let ds = Dataset::load_artifact(&root, name, "test", meta.dim,
                                        meta.task)?;
        let sketch = bundle.sketch.clone();
        router.add_lane(name, BackendKind::Sketch, move || {
            Ok(Box::new(backend::SketchEngine::new(sketch)) as _)
        }, &cfg);
        let mlp = bundle.mlp.clone();
        router.add_lane(name, BackendKind::NnRust, move || {
            Ok(Box::new(backend::MlpEngine::new(mlp)) as _)
        }, &cfg);
        let kp = bundle.kernel.params.clone();
        router.add_lane(name, BackendKind::KernelRust, move || {
            Ok(Box::new(backend::KernelEngine::new(
                repsketch::kernel::KernelModel::new(kp),
            )) as _)
        }, &cfg);
        let dir = root.join(name);
        let (batch, dim) = (meta.aot_batch, meta.dim);
        router.add_lane(name, BackendKind::NnPjrt, move || {
            let rt = Runtime::cpu()?;
            Ok(Box::new(backend::PjrtEngine {
                exe: rt.load_hlo(dir.join("nn.hlo.txt"), batch, dim)?,
            }) as _)
        }, &cfg);
        testsets.push((name, ds));
    }
    let router = Arc::new(router);

    // --- TCP server --------------------------------------------------------
    let server = Server::bind(router.clone(), "127.0.0.1:0")?;
    let addr = server.local_addr();
    let stop = server.stop_handle();
    let server_thread =
        std::thread::spawn(move || server.serve().expect("serve"));
    println!("serving 6 datasets x {} backends on {addr}\n", BACKENDS.len());

    // --- drive load through the socket, one lane at a time ----------------
    println!(
        "{:<10} {:<12} {:>8} {:>9} {:>10} {:>10} {:>12}",
        "dataset", "backend", "metric", "p50(us)", "p99(us)", "mean(us)",
        "throughput"
    );
    println!("{}", "-".repeat(78));
    for (name, ds) in &testsets {
        for kind in BACKENDS {
            let stream = std::net::TcpStream::connect(addr)?;
            stream.set_nodelay(true)?;
            let mut w = stream.try_clone()?;
            let reader = BufReader::new(stream);
            let n = n_per_lane.min(ds.len());
            // writer: stream all requests
            let reqs: Vec<String> = (0..n)
                .map(|i| {
                    let mut line = Request {
                        id: i as u64 + 1,
                        model: name.to_string(),
                        backend: kind,
                        features: ds.row(i).to_vec(),
                        want_scores: false,
                    }
                    .to_line();
                    line.push('\n');
                    line
                })
                .collect();
            let t0 = Instant::now();
            let writer = std::thread::spawn(move || {
                for line in reqs {
                    if w.write_all(line.as_bytes()).is_err() {
                        break;
                    }
                }
            });
            // reader: collect responses
            let mut preds = vec![0.0f32; n];
            let mut lats = Vec::with_capacity(n);
            let mut seen = 0usize;
            for line in reader.lines() {
                let resp = Response::parse_line(&line?)
                    .map_err(|e| anyhow::anyhow!(e))?;
                let id = resp
                    .id
                    .ok_or_else(|| anyhow::anyhow!("response without id"))?;
                let y = resp.result.map_err(|e| anyhow::anyhow!(e))?;
                preds[(id - 1) as usize] = y;
                lats.push(resp.latency_us);
                seen += 1;
                if seen == n {
                    break;
                }
            }
            let wall = t0.elapsed();
            writer.join().ok();
            lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let sub = Dataset {
                dim: ds.dim,
                task: ds.task,
                x: ds.x[..n * ds.dim].to_vec(),
                y: ds.y[..n].to_vec(),
            };
            let metric = sub.score(&preds);
            let q = |p: f64| lats[((lats.len() - 1) as f64 * p) as usize];
            println!(
                "{:<10} {:<12} {:>8.3} {:>9.0} {:>10.0} {:>10.0} {:>9.0}/s",
                name,
                kind.name(),
                metric,
                q(0.5),
                q(0.99),
                lats.iter().sum::<f64>() / lats.len() as f64,
                n as f64 / wall.as_secs_f64(),
            );
        }
    }

    println!("\nlane stats:");
    for (model, kind, submitted, batches, lat) in router.lane_stats() {
        if submitted > 0 {
            println!(
                "  {model}/{kind}: {submitted} reqs in {batches} batches \
                 (avg batch {:.1}) | {lat}",
                submitted as f64 / batches.max(1) as f64
            );
        }
    }

    stop.store(true, std::sync::atomic::Ordering::Release);
    let _ = server_thread.join();
    println!("\nserve_edge OK");
    Ok(())
}
